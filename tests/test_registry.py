"""The paper's §II-A dependency story, executable."""
import pytest

from repro.core import registry as R


def test_version_parsing():
    assert R.parse_version("1.11.0") == (1, 11, 0)
    assert R.parse_version("2.6") == (2, 6, 0)
    c = R.Constraint.parse(">=3.6.0")
    assert c.satisfied_by((3, 6, 1)) and not c.satisfied_by((3, 5, 9))


def test_resolver_picks_consistent_set():
    idx = R.default_index()
    sol = R.Resolver(idx).resolve(["tensorflow==1.11.0", "horovod>=0.15.0"])
    assert sol["tensorflow"].version == "1.11.0"
    assert sol["protobuf"].vtuple >= (3, 6, 0)
    assert "six" in sol and "numpy" in sol


def test_conflicting_roots_unresolvable_in_one_env():
    idx = R.default_index()
    with pytest.raises(R.ResolutionError):
        R.Resolver(idx).resolve(["tensorflow==1.11.0", "caffe==1.0.0"])


def test_paper_tf_then_caffe_breakage():
    """Installing Caffe after TensorFlow downgrades protobuf and breaks TF —
    the exact §II-A scenario."""
    idx = R.default_index()
    env = R.SharedEnvironment(idx)
    env.pip_install("tensorflow==1.11.0")
    assert env.check() == {}
    env.pip_install("caffe==1.0.0")
    problems = env.check()
    assert "tensorflow==1.11.0" in problems
    assert any("protobuf" in p for p in problems["tensorflow==1.11.0"])


def test_per_image_resolution_fixes_it():
    idx = R.default_index()
    r = R.Resolver(idx)
    tf_image = r.resolve(["tensorflow==1.11.0"])
    caffe_image = r.resolve(["caffe==1.0.0"])
    assert tf_image["protobuf"].vtuple >= (3, 6, 0)
    assert caffe_image["protobuf"].version == "2.6.1"


def test_offline_fetch_raises():
    idx = R.PackageIndex()
    with pytest.raises(R.OfflineViolation):
        R.Resolver(idx).resolve(["pandas>=1.0.0"])
