"""Fault-tolerant serving fleet (PR 9): deterministic fault injection,
replica health + failover, retry with backoff, load-shedding
degradation, and the chaos harness — every request either completes
bit-identical to a fault-free greedy oracle or surfaces a typed
failure, with zero leaked slots / blocks / pins on the survivors."""
import jax
import numpy as np
import pytest

from repro.serving import (CapacityError, ClusterRegistry,
                           DegradationPolicy, FaultInjector, FaultPlan,
                           FaultSpec, HealthConfig, HealthMonitor,
                           InjectedFault, Mailbox, MailboxError,
                           MockBackend, Overloaded, ReplicaCrashed,
                           ReplicaGateway, Request, RequestFailed,
                           RetryPolicy, SamplingParams, Scheduler,
                           ServingEngine, SlurmBackend, WorkerSpec,
                           launch_capsule_replicas,
                           launch_fabric_replicas, shutdown_fabric)
from repro.serving.fabric import COMPLETED, PENDING, RUNNING, Partition
from repro.serving.health import DEAD, DEGRADED, HEALTHY, QUARANTINED


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, *, slots=3, seq=48, block=8, chunk=8, prefill_batch=2,
            **kw):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         kv_block_size=block, prefill_chunk=chunk,
                         prefill_batch=prefill_batch, **kw)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _assert_no_leaks(sched):
    eng = sched.engine
    assert not sched.queue and not sched.active and not sched.prefilling
    assert not eng._inflight
    assert eng.kv.pool.in_use == 0
    assert eng.kv.free_slot_count == eng.max_slots
    if eng.prefix_cache is not None:
        eng.prefix_cache.evict(10 ** 9)
        assert eng.kv.prefix_pool.in_use == 0, "leaked prefix pins"


_ORACLE_CACHE = {}

# greedy_tie_eps armed by default in every fault/failover path: a
# salvaged request resumes in a different batch composition, and only
# eps-tolerant argmax keeps that bit-identical to the fault-free run
TIE_EPS = 1e-2


def _oracle(qwen, prompt, max_new, *, seq=48):
    """Solo fault-free greedy run of one prompt — the bit-identity
    reference a failed-over request must still reproduce."""
    key = (tuple(int(x) for x in prompt), max_new, seq)
    if key not in _ORACLE_CACHE:
        eng = _engine(qwen, seq=seq, greedy_tie_eps=TIE_EPS)
        sched = Scheduler(eng)
        rid = sched.submit(Request(prompt, SamplingParams(
            max_new_tokens=max_new, greedy=True)))
        sched.run()
        _ORACLE_CACHE[key] = sched.output(rid)
    return _ORACLE_CACHE[key]


def _requests(cfg, rng, n, max_new=6):
    return [Request(_prompt(rng, cfg, int(rng.integers(3, 12))),
                    SamplingParams(max_new_tokens=max_new, greedy=True))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# fault plans / injectors (pure — no engine)
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(kind="raise", probability=1.5)
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="explode")
    with pytest.raises(ValueError, match="site"):
        FaultSpec(kind="stall", site="decode")
    with pytest.raises(ValueError, match="latency_s"):
        FaultSpec(kind="slow", latency_s=0.0)
    with pytest.raises(ValueError, match="duration"):
        FaultSpec(kind="raise", duration=0)


def test_fault_plan_is_deterministic():
    a = FaultPlan.random(seed=11, replicas=["r0", "r1", "r2"])
    b = FaultPlan.random(seed=11, replicas=["r0", "r1", "r2"])
    assert a.specs == b.specs
    c = FaultPlan.random(seed=12, replicas=["r0", "r1", "r2"])
    assert a.specs != c.specs


def test_injector_stall_crash_and_replay():
    inj = FaultInjector([FaultSpec(kind="stall", at_step=1, duration=2)],
                        replica="r0")
    assert [inj.on_step() for _ in range(4)] == \
        ["ok", "stall", "stall", "ok"]
    assert inj.fired == [(1, "stall", "step"), (2, "stall", "step")]
    # reset() replays the schedule exactly
    inj.reset()
    assert [inj.on_step() for _ in range(4)] == \
        ["ok", "stall", "stall", "ok"]

    inj = FaultInjector([FaultSpec(kind="crash", at_step=0)], replica="r0")
    with pytest.raises(ReplicaCrashed):
        inj.on_step()
    with pytest.raises(ReplicaCrashed):   # a crash is sticky
        inj.on_step()

    inj = FaultInjector([FaultSpec(kind="raise", at_step=0, site="prefill")],
                        replica="r0")
    assert inj.on_step() == "ok"          # step-site untouched
    # the prefill-site fault fires at the step it was armed for
    inj2 = FaultInjector([FaultSpec(kind="raise", at_step=0,
                                    site="prefill")], replica="r0")
    with pytest.raises(InjectedFault):
        inj2.on_engine_op("prefill")


def test_plan_filters_by_replica():
    plan = FaultPlan([FaultSpec(kind="stall", replica="r1", at_step=0),
                      FaultSpec(kind="raise", replica="*", at_step=5)])
    assert len(plan.injector_for("r0").specs) == 1       # the wildcard
    assert len(plan.injector_for("r1").specs) == 2


# ---------------------------------------------------------------------------
# health ladder (pure)
# ---------------------------------------------------------------------------

def test_health_ladder_and_recovery():
    m = HealthMonitor(HealthConfig(degraded_after=2, quarantine_after=4))
    assert m.state == HEALTHY and m.routable
    assert m.record_step(False) is None                  # 1 bad: still ok
    tr = m.record_step(False)
    assert tr == {"from": HEALTHY, "to": DEGRADED,
                  "reason": "no_progress", "consecutive_bad": 2}
    assert m.routable                                    # degraded routes
    tr = m.record_step(True)                             # progress heals
    assert tr["to"] == HEALTHY and m.consecutive_bad == 0
    for _ in range(3):
        m.record_step(False)
    tr = m.record_step(False)
    assert tr["to"] == QUARANTINED and not m.routable and m.alive
    tr = m.mark_rejoined()
    assert tr["to"] == HEALTHY and m.rejoins == 1
    tr = m.record_failure("ReplicaCrashed()", fatal=True)
    assert tr["to"] == DEAD and not m.alive and m.failures == 1


def test_health_config_validation():
    with pytest.raises(ValueError):
        HealthConfig(degraded_after=0)
    with pytest.raises(ValueError):
        HealthConfig(degraded_after=4, quarantine_after=4)


# ---------------------------------------------------------------------------
# tentpole: crash failover is bit-identical to the fault-free oracle
# ---------------------------------------------------------------------------

def test_crash_failover_bit_identical_and_single_counted(qwen):
    """Kill one of three replicas mid-burst: every request still
    completes with the fault-free greedy oracle's exact tokens, the
    merged metrics count each logical request exactly once (retries as
    retries, one TTFT sample each), and the survivors leak nothing."""
    cfg, _ = qwen
    rng = np.random.default_rng(3)
    plan = FaultPlan([FaultSpec(kind="crash", replica="replica1",
                                at_step=3)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, greedy_tie_eps=TIE_EPS) for _ in range(3)],
        tracing=True, fault_plan=plan)
    reqs = _requests(cfg, rng, 6)
    handles = [gw.submit(r) for r in reqs]
    gw.drain()

    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed), out
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))

    assert gw.health[1].state == DEAD
    stats = gw.stats()
    assert stats["fleet"]["failovers"] == 1
    assert stats["fleet"]["requests_failed"] == 0
    # single-count invariants: 6 logical submits, 6 completions, the
    # re-submits counted as retries, exactly one TTFT sample each
    tot = stats["totals"]
    assert tot["requests_submitted"] == 6
    assert tot["requests_completed"] == 6
    assert tot["requests_retried"] >= 1
    assert sum(len(rep.scheduler.metrics.ttft_s())
               for rep in gw.replicas) == 6
    # replica_* events are on the merged timeline
    kinds = {e["kind"] for e in gw.trace_events()}
    assert {"replica_health", "replica_failover",
            "replica_retry"} <= kinds
    for i, rep in enumerate(gw.replicas):
        if i != 1:                      # the dead capsule's pool died
            _assert_no_leaks(rep.scheduler)


def test_failover_preserves_emitted_prefix(qwen):
    """A request salvaged *mid-decode* resumes with its emitted-so-far
    tokens (recompute resume) — the final output is one contiguous
    sequence, not a restart."""
    cfg, _ = qwen
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, cfg, 6)
    plan = FaultPlan([FaultSpec(kind="crash", replica="replica0",
                                at_step=4)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, greedy_tie_eps=TIE_EPS) for _ in range(2)],
        tracing=True, fault_plan=plan)
    h = gw.submit(Request(prompt, SamplingParams(max_new_tokens=10,
                                                 greedy=True)))
    # step until the crash fires and the request lands on replica1
    for _ in range(40):
        if not gw.has_work:
            break
        gw.step()
    assert gw.health[0].state == DEAD
    out = gw.result(h)
    assert not isinstance(out, RequestFailed)
    rec = gw._requests[h]
    assert rec.attempts == 1 and rec.current[0] == 1
    np.testing.assert_array_equal(out, _oracle(qwen, prompt, 10))


# ---------------------------------------------------------------------------
# satellite: drain() no longer hangs on a wedged replica
# ---------------------------------------------------------------------------

def test_stalled_replica_is_quarantined_and_drain_completes(qwen):
    """Regression for the drain()/run() hang: a replica whose step()
    returns True without doing anything is detected by the progress
    watchdog, quarantined, and its work re-homed — drain returns."""
    cfg, _ = qwen
    rng = np.random.default_rng(7)
    plan = FaultPlan([FaultSpec(kind="stall", replica="replica0",
                                at_step=1, duration=200)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, greedy_tie_eps=TIE_EPS) for _ in range(2)],
        tracing=True, fault_plan=plan,
        health=HealthConfig(degraded_after=2, quarantine_after=4,
                            auto_rejoin=False))
    reqs = _requests(cfg, rng, 4, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    gw.drain()                           # must not hang
    assert gw.health[0].state == QUARANTINED
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    _assert_no_leaks(gw.replicas[1].scheduler)


def test_watchdog_raises_when_health_cannot_quarantine(qwen):
    """With quarantine effectively disabled, the run() watchdog raises
    after stall_patience no-progress steps instead of spinning forever
    — the old failure mode, now loud."""
    plan = FaultPlan([FaultSpec(kind="stall", replica="replica0",
                                at_step=0, duration=10 ** 6)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen)], fault_plan=plan, stall_patience=6,
        health=HealthConfig(degraded_after=10 ** 6,
                            quarantine_after=10 ** 6 + 1))
    gw.submit(Request(np.array([1, 2, 3], np.int32),
                      SamplingParams(max_new_tokens=2, greedy=True)))
    with pytest.raises(RuntimeError, match="no progress"):
        gw.run()


# ---------------------------------------------------------------------------
# retry budget / typed failures
# ---------------------------------------------------------------------------

def test_exhausted_requests_fail_typed_not_hang(qwen):
    """Single replica crashes: no survivor to retry on, so every
    request resolves to a typed RequestFailed from result() — and a
    fresh submit raises Overloaded."""
    plan = FaultPlan([FaultSpec(kind="crash", replica="replica0",
                                at_step=2)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen)], tracing=True, fault_plan=plan)
    h = gw.submit(Request(np.array([1, 2, 3, 4], np.int32),
                          SamplingParams(max_new_tokens=8, greedy=True)))
    gw.drain()
    out = gw.result(h)
    assert isinstance(out, RequestFailed)
    assert out.reason in ("no_routable_replica", "retry_budget_exhausted")
    assert out.handle == h and out.attempts >= 1
    assert gw.stats()["totals"]["requests_failed"] == 1
    assert "request_failed" in {e["kind"] for e in gw.trace_events()}
    gw.draining = False                  # re-open admission: still no
    with pytest.raises(Overloaded):      # routable replica to take it
        gw.submit(Request(np.array([1], np.int32)))


def test_retry_backoff_schedule():
    p = RetryPolicy(max_retries=3, backoff_base_steps=2, backoff_factor=3)
    assert [p.backoff_steps(a) for a in (1, 2, 3)] == [2, 6, 18]


# ---------------------------------------------------------------------------
# quarantine exit / rejoin
# ---------------------------------------------------------------------------

def test_quarantined_replica_rejoins_and_serves(qwen):
    """A transient stall quarantines the replica; after the cooldown it
    auto-rejoins (fresh scheduler, same engine, exhausted fault NOT
    replayed) and serves new traffic again."""
    cfg, _ = qwen
    rng = np.random.default_rng(9)
    plan = FaultPlan([FaultSpec(kind="stall", replica="replica0",
                                at_step=1, duration=6)])
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, greedy_tie_eps=TIE_EPS) for _ in range(2)],
        tracing=True, fault_plan=plan,
        health=HealthConfig(degraded_after=2, quarantine_after=3,
                            rejoin_cooldown_steps=2))
    reqs = _requests(cfg, rng, 3, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    gw.drain()
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    # keep stepping until the cooldown elapses and replica0 rejoins
    for _ in range(10):
        if gw.health[0].state == HEALTHY:
            break
        gw.step()
    assert gw.health[0].state == HEALTHY and gw.health[0].rejoins == 1
    kinds = {e["kind"] for e in gw.trace_events()}
    assert "replica_rejoin" in kinds
    # the rejoined replica serves again (admission was re-opened by the
    # fresh scheduler carrying the drain flag of the gateway — reset it
    # for the post-drain continuation of this test)
    gw.draining = False
    for rep in gw.replicas:
        rep.scheduler.draining = False
    r2 = Request(_prompt(rng, cfg, 5),
                 SamplingParams(max_new_tokens=3, greedy=True))
    h2 = gw.submit(r2)
    gw.drain()
    out = gw.result(h2)
    assert not isinstance(out, RequestFailed)
    np.testing.assert_array_equal(out, _oracle(qwen, r2.prompt, 3))


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_overload_sheds_and_shrinks_budget_then_recovers(qwen):
    cfg, _ = qwen
    rng = np.random.default_rng(13)
    gw = ReplicaGateway.from_engines(
        [_engine(qwen)], tracing=True,
        degradation=DegradationPolicy(shed_queue_depth=3,
                                      recover_steps=2,
                                      budget_shrink=0.5),
        prefill_token_budget=8)
    reqs = _requests(cfg, rng, 6, max_new=2)
    handles = [gw.submit(r) for r in reqs]
    gw.step()                                      # ladder arms
    assert gw.degraded
    sched = gw.replicas[0].scheduler
    assert sched.prefill_token_budget == 4         # shrunk
    with pytest.raises(Overloaded):                # shedding at submit
        gw.submit(reqs[0])
    assert gw.shed_requests == 1
    gw.drain()
    assert not gw.degraded                         # queue emptied
    assert sched.prefill_token_budget == 8         # restored
    for h in handles:
        assert not isinstance(gw.result(h), RequestFailed)
    stats = gw.stats()
    assert stats["totals"]["requests_shed"] == 1
    assert stats["fleet"]["degraded_transitions"] == 1
    evs = [e for e in gw.trace_events() if e["kind"] == "overload_shed"]
    assert [e["active"] for e in evs] == [True, False]  # edge-triggered


def test_degraded_caps_breached_tenant_max_new(qwen):
    cfg, _ = qwen
    gw = ReplicaGateway.from_engines(
        [_engine(qwen)], tracing=True,
        degradation=DegradationPolicy(max_new_cap=3))
    # force the degraded state + an active breach for tenant "bulk"
    gw.degraded = True
    gw._breached_tenants = lambda: {"bulk"}
    h = gw.submit(Request(np.array([1, 2, 3], np.int32),
                          SamplingParams(max_new_tokens=12, greedy=True),
                          tenant="bulk"))
    gw.drain()
    out = gw.result(h)
    assert not isinstance(out, RequestFailed) and len(out) == 3
    assert gw.capped_requests == 1
    caps = [e for e in gw.trace_events() if e["kind"] == "overload_cap"]
    assert caps and caps[0]["orig_max_new"] == 12 \
        and caps[0]["capped_max_new"] == 3


# ---------------------------------------------------------------------------
# satellite: result() / launch_capsule_replicas error paths
# ---------------------------------------------------------------------------

def test_result_unknown_and_unfinished_handles(qwen):
    gw = ReplicaGateway.from_engines([_engine(qwen)])
    with pytest.raises(KeyError, match="unknown request handle"):
        gw.result((0, 99))
    with pytest.raises(KeyError, match="malformed request handle"):
        gw.result("nope")
    h = gw.submit(Request(np.array([1, 2, 3], np.int32),
                          SamplingParams(max_new_tokens=2, greedy=True)))
    with pytest.raises(RuntimeError, match="not finished"):
        gw.result(h)
    gw.drain()
    assert len(gw.result(h)) == 2


def test_launch_capsule_replicas_error_paths(qwen, tmp_path):
    with pytest.raises(ValueError, match="at least one replica"):
        launch_capsule_replicas(0, lambda: _engine(qwen), tmp_path)
    with pytest.raises(TypeError, match="callable"):
        launch_capsule_replicas(1, "not-a-factory", tmp_path)

    def exploding_factory():
        raise RuntimeError("model weights missing")

    with pytest.raises(RuntimeError, match="model weights missing"):
        launch_capsule_replicas(1, exploding_factory, tmp_path)


# ---------------------------------------------------------------------------
# chaos harness: random fault plans, every request resolves correctly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_random_faults_resolve_every_request(qwen, seed):
    """Random seeded fault schedule over a 2-replica fleet: after
    drain, every handle resolves to either the fault-free oracle's
    exact tokens or a typed RequestFailed — no hangs, no leaks on
    routable survivors, no double-counted submits."""
    cfg, _ = qwen
    rng = np.random.default_rng(100 + seed)
    plan = FaultPlan.random(seed=seed, replicas=["replica0", "replica1"],
                            n_faults=3, max_step=8)
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, greedy_tie_eps=TIE_EPS) for _ in range(2)],
        tracing=True, fault_plan=plan,
        health=HealthConfig(degraded_after=2, quarantine_after=3,
                            rejoin_cooldown_steps=4))
    reqs = _requests(cfg, rng, 5, max_new=5)
    handles = []
    for i, r in enumerate(reqs):
        try:
            handles.append((gw.submit(r), r))
        except Overloaded:
            handles.append((None, r))
        if i % 2:
            gw.step()                   # interleave bursts with steps
    gw.drain()

    completed = 0
    for h, r in handles:
        if h is None:
            continue
        out = gw.result(h)
        if isinstance(out, RequestFailed):
            assert out.reason
            continue
        completed += 1
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    submitted = sum(1 for h, _ in handles if h is not None)
    tot = gw.stats()["totals"]
    assert tot["requests_submitted"] == submitted
    assert tot["requests_completed"] == completed
    assert tot["requests_failed"] == submitted - completed
    for i, rep in enumerate(gw.replicas):
        if gw.health[i].routable:
            _assert_no_leaks(rep.scheduler)


# ---------------------------------------------------------------------------
# cross-process fabric: MockBackend drives the real worker + mailbox
# code deterministically — the same paths LocalProcessBackend runs as
# subprocesses in benchmarks/fabric.py
# ---------------------------------------------------------------------------

def _mock_fleet(qwen, tmp_path, n=2, *, backend_kw=None, **gateway_kw):
    backend = MockBackend(
        engine_factory=lambda name: _engine(qwen, greedy_tie_eps=TIE_EPS),
        **(backend_kw or {}))
    gateway_kw.setdefault("tracing", True)
    gw = launch_fabric_replicas(n, backend, tmp_path / "spool",
                                **gateway_kw)
    return backend, gw


def _kill_when_inflight(gw, backend, victim, *, action=None):
    """Step until the victim's heartbeat shows in-flight work, then pull
    the chaos lever (default: SIGKILL analogue)."""
    for _ in range(100):
        gw.step()
        if victim.active or victim.prefilling:
            (action or backend.kill)(victim.handle)
            return
    pytest.fail("victim never reported in-flight work")


def test_fabric_mock_round_trip_bit_identical(qwen, tmp_path):
    """Fault-free mock fleet: every request crosses the mailbox twice
    (submit in, result out) and still matches the solo oracle exactly;
    shutdown releases the registry capacity and finalizes the workers."""
    cfg, _ = qwen
    rng = np.random.default_rng(21)
    backend, gw = _mock_fleet(qwen, tmp_path)
    assert backend.registry.free_nodes("general") == 6    # 2 of 8 committed
    reqs = _requests(cfg, rng, 5, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    gw.drain()
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    shutdown_fabric(gw)
    assert backend.registry.free_nodes("general") == 8
    for rep in gw.replicas:
        status = (tmp_path / "spool" / rep.name / "status.json")
        assert status.exists()


def test_fabric_crash_failover_bit_identical(qwen, tmp_path):
    """Kill a mock worker while its heartbeat shows in-flight requests:
    the gateway sees the job FAIL, marks the replica DEAD, salvages from
    the last heartbeat's emitted map, and the failed-over outputs stay
    bit-identical to the oracle."""
    cfg, _ = qwen
    rng = np.random.default_rng(23)
    backend, gw = _mock_fleet(qwen, tmp_path)
    reqs = _requests(cfg, rng, 6, max_new=5)
    handles = [gw.submit(r) for r in reqs]
    victim = gw.replicas[0].scheduler
    _kill_when_inflight(gw, backend, victim)
    gw.drain()
    assert gw.health[0].state == DEAD
    assert gw.stats()["fleet"]["failovers"] == 1
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    kinds = {e["kind"] for e in gw.trace_events()}
    assert {"replica_health", "replica_failover", "replica_retry"} <= kinds


def test_fabric_stale_heartbeats_quarantine_and_salvage(qwen, tmp_path):
    """A wedged worker (process alive, heartbeat seq frozen — a hung
    filesystem client) stops making observable progress: the ladder
    quarantines it and its work re-homes bit-identically."""
    cfg, _ = qwen
    rng = np.random.default_rng(25)
    backend, gw = _mock_fleet(
        qwen, tmp_path,
        health=HealthConfig(degraded_after=2, quarantine_after=4,
                            auto_rejoin=False))
    reqs = _requests(cfg, rng, 5, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    victim = gw.replicas[0].scheduler
    _kill_when_inflight(gw, backend, victim, action=backend.stall)
    gw.drain()
    assert gw.health[0].state == QUARANTINED
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))


def test_fabric_quarantined_replica_respawns_and_serves(qwen, tmp_path):
    """Quarantine auto-rejoin on a remote replica goes through
    respawn(): the old job is cancelled, a *fresh worker job* is
    submitted for the same spec, and the relaunched replica serves new
    traffic."""
    cfg, _ = qwen
    rng = np.random.default_rng(27)
    backend, gw = _mock_fleet(
        qwen, tmp_path,
        health=HealthConfig(degraded_after=2, quarantine_after=3,
                            rejoin_cooldown_steps=2))
    reqs = _requests(cfg, rng, 4, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    victim = gw.replicas[0].scheduler
    old_job = victim.handle.job_id
    _kill_when_inflight(gw, backend, victim, action=backend.stall)
    gw.drain()
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))
    for _ in range(10):
        if gw.health[0].state == HEALTHY:
            break
        gw.step()
    assert gw.health[0].state == HEALTHY and gw.health[0].rejoins == 1
    assert gw.replicas[0].scheduler.handle.job_id != old_job
    gw.draining = False
    for rep in gw.replicas:
        rep.scheduler.draining = False
    r2 = Request(_prompt(rng, cfg, 5),
                 SamplingParams(max_new_tokens=3, greedy=True))
    h2 = gw.submit(r2)
    gw.drain()
    out = gw.result(h2)
    assert not isinstance(out, RequestFailed)
    np.testing.assert_array_equal(out, _oracle(qwen, r2.prompt, 3))


def test_fabric_mock_fault_plan_crash(qwen, tmp_path):
    """The PR 9 chaos harness extends across the (simulated) process
    boundary: a FaultPlan crash wired into a mock worker's scheduler
    surfaces as a FAILED job -> DEAD replica -> bit-identical failover."""
    cfg, _ = qwen
    rng = np.random.default_rng(29)
    plan = FaultPlan([FaultSpec(kind="crash", replica="replica0",
                                at_step=2)])
    backend, gw = _mock_fleet(qwen, tmp_path,
                              backend_kw={"fault_plan": plan})
    reqs = _requests(cfg, rng, 4, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    gw.drain()
    assert gw.health[0].state == DEAD
    assert "crash" in (gw.replicas[0].scheduler.handle.error or "").lower() \
        or gw.replicas[0].scheduler.handle.error
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))


# ---------------------------------------------------------------------------
# mailbox transport fault cases: truncated / partial messages, corrupt
# heartbeats, duplicate results — typed failures or idempotent no-ops
# ---------------------------------------------------------------------------

def test_mailbox_truncated_message_is_typed_and_lossless(tmp_path):
    mb = Mailbox(tmp_path / "spool", "r0")
    mb.post_to_worker("drain")
    (mb.inbox / "00000002.99.json").write_text('{"kind": "sub')  # truncated
    with pytest.raises(MailboxError, match="corrupt"):
        mb.collect_inbox()
    # nothing was consumed: the valid message sorted before the corrupt
    # one must still be delivered once the spool is repaired
    with pytest.raises(MailboxError):
        mb.collect_inbox()
    (mb.inbox / "00000002.99.json").unlink()
    assert [m["kind"] for m in mb.collect_inbox()] == ["drain"]
    # a message that parses but has no 'kind' is malformed, same typing
    (mb.inbox / "00000003.99.json").write_text('{"rid": 1}')
    with pytest.raises(MailboxError, match="no 'kind'"):
        mb.collect_inbox()


def test_mailbox_inflight_tmp_files_are_invisible(tmp_path):
    """A crashed writer leaves a ``.tmp`` file mid-write; readers must
    never see it — atomic rename means a ``*.json`` is complete by
    construction."""
    mb = Mailbox(tmp_path / "spool", "r0")
    (mb.inbox / "00000001.99.json.tmp").write_text('{"kind": "sub')
    assert mb.collect_inbox() == []
    mb.post_to_worker("stop")
    assert [m["kind"] for m in mb.collect_inbox()] == ["stop"]


def test_mailbox_corrupt_heartbeat_is_typed(tmp_path):
    mb = Mailbox(tmp_path / "spool", "r0")
    assert mb.read_heartbeat() is None          # no heartbeat yet: None
    mb.write_heartbeat({"seq": 1})
    assert mb.read_heartbeat() == {"seq": 1}
    mb.heartbeat_path.write_text('{"seq": ')    # spool corruption
    with pytest.raises(MailboxError, match="corrupt heartbeat"):
        mb.read_heartbeat()
    mb.heartbeat_path.write_text('[1, 2]')      # parses, wrong shape
    with pytest.raises(MailboxError, match="not an object"):
        mb.read_heartbeat()


def test_fabric_corrupt_spool_climbs_health_ladder(qwen, tmp_path):
    """A corrupt message file in a live replica's outbox surfaces as a
    MailboxError every gateway step — a transient (non-fatal) failure
    that climbs the ladder to QUARANTINED, after which the victim's work
    re-homes and completes bit-identically."""
    cfg, _ = qwen
    rng = np.random.default_rng(31)
    backend, gw = _mock_fleet(
        qwen, tmp_path,
        health=HealthConfig(degraded_after=2, quarantine_after=4,
                            auto_rejoin=False))
    reqs = _requests(cfg, rng, 4, max_new=4)
    handles = [gw.submit(r) for r in reqs]
    victim = gw.replicas[0].scheduler
    # disk fault: an unparseable message lands in the victim's outbox
    (victim.mailbox.outbox / "00000000.0.json").write_text("garbage")
    gw.drain()
    assert gw.health[0].state == QUARANTINED
    assert "MailboxError" in gw.health[0].last_error
    for h, r in zip(handles, reqs):
        out = gw.result(h)
        assert not isinstance(out, RequestFailed)
        np.testing.assert_array_equal(
            out, _oracle(qwen, r.prompt, r.params.max_new_tokens))


def test_fabric_duplicate_result_is_idempotent(qwen, tmp_path):
    """A slow worker racing its own failover can deliver a result for a
    request the gateway already resolved elsewhere — the duplicate must
    be dropped, not clobber the canonical output."""
    cfg, _ = qwen
    rng = np.random.default_rng(33)
    backend, gw = _mock_fleet(qwen, tmp_path, n=1)
    r = Request(_prompt(rng, cfg, 5),
                SamplingParams(max_new_tokens=4, greedy=True))
    h = gw.submit(r)
    gw.drain()
    out1 = np.asarray(gw.result(h))
    rs = gw.replicas[0].scheduler
    # forge a late duplicate with different tokens for the finished rid
    rs.mailbox.post_to_gateway("result", rid=0, tokens=[1, 2, 3])
    rs.step()
    np.testing.assert_array_equal(rs.done[0], out1)
    np.testing.assert_array_equal(gw.result(h), out1)


# ---------------------------------------------------------------------------
# registry + slurm backend lifecycle (no engine)
# ---------------------------------------------------------------------------

def test_fabric_capacity_validated_before_submit(tmp_path):
    reg = ClusterRegistry()
    reg.add(Partition("tiny", nodes=2))
    backend = SlurmBackend(registry=reg)
    spool = tmp_path / "spool"
    for i in range(2):
        backend.submit(WorkerSpec(replica=f"replica{i}", spool=spool,
                                  partition="tiny"))
    with pytest.raises(CapacityError, match="0 free of 2"):
        backend.submit(WorkerSpec(replica="replica2", spool=spool,
                                  partition="tiny"))
    assert len(backend.jobs) == 2          # the refused submit left no job
    with pytest.raises(CapacityError, match="unknown partition"):
        backend.submit(WorkerSpec(replica="replica3", spool=spool,
                                  partition="gpu"))
    assert reg.summary() == [{"partition": "tiny", "nodes": 2,
                              "committed": 2, "free": 0}]


def test_fabric_slurm_backend_renders_and_tracks_lifecycle(tmp_path):
    """SlurmBackend renders a real sbatch script through launch/slurm
    (shell-quoted worker argv, fabric env) and tracks the job off the
    worker's spool signals: heartbeat -> RUNNING, status -> COMPLETED."""
    import json as _json
    backend = SlurmBackend()
    spool = tmp_path / "spool"
    spec = WorkerSpec(replica="replica0", spool=spool,
                      model_spec={"seed": 3}, image_dir="/tmp/caps/img")
    h = backend.submit(spec)
    script = (spool / "jobs" / f"{h.job_id}-replica0.sbatch").read_text()
    assert "#SBATCH --job-name=fabric-replica0" in script
    assert "ch-run /tmp/caps/img" in script
    assert "-m repro.serving.fabric.worker" in script
    assert "--image-dir /tmp/caps/img" in script
    assert "'{\"seed\": 3}'" in script      # JSON blob shell-quoted
    assert f"export REPRO_FABRIC_SPOOL={str(spool)}" in script
    assert backend.poll(h) == PENDING
    mb = Mailbox(spool, "replica0")
    mb.write_heartbeat({"seq": 1})
    assert backend.poll(h) == RUNNING
    (mb.home / "status.json").write_text(
        _json.dumps({"state": "completed", "error": ""}))
    assert backend.poll(h) == COMPLETED
    assert backend.registry.free_nodes("general") == 8   # released
    backend.cancel(h)                                    # idempotent
    assert backend.poll(h) == COMPLETED
