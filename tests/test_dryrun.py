"""Dry-run integration tests (slow: real XLA compiles in a subprocess with
512 host devices).  The full 40-pair x 2-mesh sweep runs via
``python -m repro.launch.dryrun --all --both-meshes``; here we gate a
representative slice in CI."""
import json
import os
import subprocess
import sys

import pytest

PAIRS = [
    ("qwen2-0.5b", "train_4k"),        # dense train
    ("dbrx-132b", "decode_32k"),       # MoE decode, seq-sharded cache
    ("mamba2-1.3b", "long_500k"),      # SSM long-context decode
    ("whisper-small", "prefill_32k"),  # enc-dec prefill
]


def _run_dryrun(args, timeout=560):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd="/root/repo",
        timeout=timeout)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", PAIRS)
def test_dryrun_pair_compiles(arch, shape, tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--json", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["flops"] > 0 and rec["chips"] == 256
    # fits a 16 GiB-HBM chip: arguments + scheduled peak
    assert rec["argument_size"] < 16 * 2**30


@pytest.mark.slow
def test_dryrun_multipod_compiles(tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run_dryrun(["--arch", "qwen2-0.5b", "--shape", "decode_32k",
                     "--multi-pod", "--json", str(out)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok" and rec["chips"] == 512


@pytest.mark.slow
def test_whisper_long500k_is_skipped(tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run_dryrun(["--arch", "whisper-small", "--shape", "long_500k",
                     "--json", str(out)])
    assert r.returncode == 0
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "skip"


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs.1 = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %z), dimensions={0}
  %done = f32[4]{0} all-gather-done(f32[4]{0} %start)
    """
    b = collective_bytes(hlo)
    assert b["all-gather"] == 16 * 1024 * 2
    assert b["all-reduce"] == 256 * 4
    assert b["reduce-scatter"] == 8 * 32 * 4


def test_sweep_results_if_present():
    """Validate the committed full-sweep results: 80 records, 0 failures,
    every ok record fits HBM on arguments."""
    path = "/root/repo/results/dryrun_all.jsonl"
    if not os.path.exists(path):
        pytest.skip("full sweep results not generated yet")
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) == 80
    assert sum(r["status"] == "fail" for r in recs) == 0
    ok = [r for r in recs if r["status"] == "ok"]
    assert len(ok) == 78                      # 2 documented whisper skips
    for r in ok:
        assert r["argument_size"] < 16 * 2**30, (r["arch"], r["shape"])
