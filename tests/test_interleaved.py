"""Interleaved prefill/decode scheduling (SplitFuse-style): the
token-budgeted scheduler round, resumable mid-prompt prefill cursors,
mid-prefill preemption, jitter telemetry, and the hardened invariant
stress harness (bit-identical greedy outputs vs the dense oracle, zero
leaked slots / blocks / prefix pins under random workloads)."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import (OutOfBlocks, ReplicaGateway, Request,
                           SamplingParams, Scheduler, ServingEngine,
                           ServingMetrics, merge_summaries)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, *, slots=3, seq=48, block=8, chunk=8, prefill_batch=2,
            **kw):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         kv_block_size=block, prefill_chunk=chunk,
                         prefill_batch=prefill_batch, **kw)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _assert_no_leaks(sched):
    """The invariant triad: no live scheduler state, no in-flight
    cursors, every slot and KV block back in the pool, and zero prefix
    pins held once the tree itself is evicted."""
    eng = sched.engine
    assert not sched.queue and not sched.active and not sched.prefilling
    assert not eng._inflight
    assert eng.kv.pool.in_use == 0
    assert eng.kv.free_slot_count == eng.max_slots
    if eng.prefix_cache is not None:
        eng.prefix_cache.evict(10 ** 9)    # drop the tree's own refs
        assert eng.kv.prefix_pool.in_use == 0, "leaked prefix pins"


_ORACLE_CACHE = {}


def _dense_oracle(qwen, prompts, max_news, *, seq=48, slots=3):
    """Greedy outputs served on the dense layout — the bit-identity
    reference for every interleaved variant (cached per workload so
    parametrized sweeps don't rebuild identical oracle engines)."""
    key = (tuple(tuple(int(x) for x in p) for p in prompts),
           tuple(max_news), seq, slots)
    if key not in _ORACLE_CACHE:
        eng = _engine(qwen, slots=slots, seq=seq)
        sched = Scheduler(eng)
        rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=m,
                                                       greedy=True)))
                for p, m in zip(prompts, max_news)]
        sched.run()
        _ORACLE_CACHE[key] = [sched.output(r) for r in rids]
    return _ORACLE_CACHE[key]


# ---------------------------------------------------------------------------
# the budgeted round / resumable cursors
# ---------------------------------------------------------------------------

def test_budget_must_be_positive(qwen):
    with pytest.raises(ValueError, match="prefill_token_budget"):
        Scheduler(_engine(qwen), prefill_token_budget=0)


@pytest.mark.parametrize("paged", [False, True])
def test_advance_prefill_is_resumable(qwen, paged):
    """Engine-level cursor API: a tiny budget suspends mid-prompt, the
    cursor's state survives between calls, and the completed row's
    logits match the run-to-completion path bit-for-bit."""
    eng = _engine(qwen, paged=paged)
    cfg, _ = qwen
    prompt = ((np.arange(21) * 5 + 2) % cfg.vocab_size).astype(np.int32)
    ref_eng = _engine(qwen, paged=paged)
    slot_ref, ref = ref_eng.prefill_into_slots([prompt])[0]

    [cur] = eng.begin_prefill([prompt])
    assert cur.slot in eng._inflight and cur.pos == 0
    done = eng.advance_prefill(token_budget=1)     # one chunk round only
    assert done == [] and 0 < cur.pos < len(prompt)
    assert eng.inflight_prefill_tokens == len(prompt) - cur.pos
    mid = cur.pos
    done = eng.advance_prefill(token_budget=10 ** 9)
    assert done == [cur] and cur.done and cur.pos == len(prompt)
    assert mid < cur.pos and not eng._inflight
    np.testing.assert_array_equal(np.asarray(cur.last_logits), ref)
    eng.free_slot(cur.slot)
    ref_eng.free_slot(slot_ref)


def test_budgeted_step_interleaves_decode_with_prefill(qwen):
    """The tentpole behavior: while a long admission's prefill is still
    in flight across steps, the running sequence keeps emitting one
    token per step instead of stalling for the whole wave."""
    cfg, _ = qwen
    eng = _engine(qwen, paged=True)
    sched = Scheduler(eng, prefill_token_budget=8)   # one (2, 8) round/step
    rng = np.random.default_rng(0)
    r_long = sched.submit(Request(_prompt(rng, cfg, 4),
                                  SamplingParams(max_new_tokens=12,
                                                 greedy=True)))
    sched.step()                                    # long-runner admitted
    assert len(sched.active) == 1
    st_long = next(iter(sched.active.values()))
    n0 = len(st_long.emitted)
    r_burst = sched.submit(Request(_prompt(rng, cfg, 33),
                                   SamplingParams(max_new_tokens=2,
                                                  greedy=True)))
    interleaved_steps = 0
    while sched.has_work:
        sched.step()
        if sched.prefilling:                        # burst mid-prefill...
            assert len(st_long.emitted) > n0        # ...decode advanced
            interleaved_steps += 1
            n0 = len(st_long.emitted)
    # a 33-token prompt at 8 executed tokens/step is mid-flight for
    # several consecutive fused rounds
    assert interleaved_steps >= 3
    assert len(sched.output(r_long)) == 12
    assert len(sched.output(r_burst)) == 2
    _assert_no_leaks(sched)


@pytest.mark.parametrize("budget", [8, 16, 40, None])
@pytest.mark.parametrize("paged", [False, True])
def test_budgets_are_bit_identical_to_dense_oracle(qwen, budget, paged):
    """Interleaving changes WHEN prefill chunks run, never what they
    compute: greedy outputs are bit-identical across budgets, layouts,
    and the unbudgeted wave-at-once path."""
    cfg, _ = qwen
    rng = np.random.default_rng(7)
    prompts = [_prompt(rng, cfg, n) for n in (3, 19, 33, 9, 26)]
    max_news = [6, 3, 5, 8, 2]
    oracle = _dense_oracle(qwen, prompts, max_news)
    eng = _engine(qwen, paged=paged, prefix_cache_blocks=16)
    sched = Scheduler(eng, prefill_token_budget=budget)
    rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=m,
                                                   greedy=True)))
            for p, m in zip(prompts, max_news)]
    sched.run()
    for r, ref in zip(rids, oracle):
        np.testing.assert_array_equal(sched.output(r), ref)
    _assert_no_leaks(sched)


def test_long_prefill_not_starved_by_short_arrivals(qwen):
    """Advance rounds are FIFO by begin order: a long prompt keeps
    advancing under a sustained stream of later short admissions
    instead of being starved by a shortest-first policy (its TTFT stays
    bounded)."""
    cfg, _ = qwen
    eng = _engine(qwen, slots=6, paged=True)     # Bp=2: 2 rows per round
    sched = Scheduler(eng, prefill_token_budget=8)
    rng = np.random.default_rng(8)
    r_long = sched.submit(Request(_prompt(rng, cfg, 33),
                                  SamplingParams(max_new_tokens=1,
                                                 greedy=True)))
    steps = 0
    while r_long not in sched.done and steps < 12:
        for _ in range(2):                       # two shorts per step
            sched.submit(Request(_prompt(rng, cfg, 6),
                                 SamplingParams(max_new_tokens=1,
                                                greedy=True)))
        sched.step()
        steps += 1
    # 33 tokens at >= 8/step alongside one short per round: done well
    # inside the cap; shortest-first would still be at pos 0 here
    assert r_long in sched.done
    sched.run()                                  # drain the short tail
    _assert_no_leaks(sched)


def test_dense_staging_cache_materializes_lazily(qwen):
    """Dense co-admission holds one cursor per prompt but at most ONE
    transient batch-1 stripe: a cursor's staging cache appears at its
    first chunk and is dropped at completion, so a deep admission batch
    can't multiply peak prefill memory."""
    cfg, _ = qwen
    eng = _engine(qwen)                          # dense, chunk=8
    prompts = [((np.arange(12) * k + 1) % cfg.vocab_size).astype(np.int32)
               for k in (3, 5, 7)]
    cursors = eng.begin_prefill(prompts)
    assert all(c.dense_cache is None for c in cursors)
    eng.advance_prefill(token_budget=1)          # one chunk: cursor 0 only
    assert cursors[0].dense_cache is not None
    assert cursors[1].dense_cache is None and cursors[2].dense_cache is None
    done = eng.advance_prefill()
    assert len(done) == 3
    assert all(c.dense_cache is None for c in cursors)   # dropped on write
    for c in cursors:
        eng.free_slot(c.slot)


# ---------------------------------------------------------------------------
# mid-prefill preemption (paged + dense)
# ---------------------------------------------------------------------------

def test_mid_prefill_preemption_paged(qwen):
    """Decode-time OutOfBlocks while a slot is partially prefilled:
    the mid-prefill admission (the youngest) is the victim — cursor
    cancelled, blocks and pins released, request re-queued — and every
    request still completes with oracle-identical output."""
    cfg, _ = qwen
    rng = np.random.default_rng(1)
    long_p, burst_p = _prompt(rng, cfg, 14), _prompt(rng, cfg, 24)
    oracle = _dense_oracle(qwen, [long_p, burst_p], [14, 2])
    # pool of 5 blocks: long holds 2 and must grow to a 3rd at pos 17
    # while the burst's 3 claimed blocks keep the pool dry
    eng = _engine(qwen, paged=True, num_blocks=5)
    sched = Scheduler(eng, prefill_token_budget=8)
    r_long = sched.submit(Request(long_p, SamplingParams(max_new_tokens=14,
                                                         greedy=True)))
    while not sched.active:                       # long admitted + decoding
        sched.step()
    assert len(sched.active) == 1
    r_burst = sched.submit(Request(burst_p, SamplingParams(max_new_tokens=2,
                                                           greedy=True)))
    sched.step()
    assert sched.prefilling, "burst should be suspended mid-prefill"
    saw_mid_prefill_preemption = False
    while sched.has_work:
        was_prefilling = bool(sched.prefilling)
        pre = sched.preemptions
        sched.step()
        if sched.preemptions > pre and was_prefilling:
            saw_mid_prefill_preemption = True
            assert not sched.prefilling           # cursor cancelled...
            assert not eng._inflight
            assert any(st.rid == r_burst for st in sched.queue)  # ...requeued
    assert saw_mid_prefill_preemption
    np.testing.assert_array_equal(sched.output(r_long), oracle[0])
    np.testing.assert_array_equal(sched.output(r_burst), oracle[1])
    _assert_no_leaks(sched)


def test_mid_prefill_preemption_dense(qwen, monkeypatch):
    """Dense layout: the pool can't physically run dry, so inject one
    decode-time OutOfBlocks while a prefill is suspended — the same
    requeue path must run (staging cache discarded, no double-free)."""
    cfg, _ = qwen
    rng = np.random.default_rng(2)
    long_p, burst_p = _prompt(rng, cfg, 5), _prompt(rng, cfg, 30)
    oracle = _dense_oracle(qwen, [long_p, burst_p], [10, 3])
    eng = _engine(qwen)
    sched = Scheduler(eng, prefill_token_budget=8)
    r_long = sched.submit(Request(long_p, SamplingParams(max_new_tokens=10,
                                                         greedy=True)))
    sched.step()
    r_burst = sched.submit(Request(burst_p, SamplingParams(max_new_tokens=3,
                                                           greedy=True)))
    sched.step()
    assert sched.prefilling
    real = eng.kv.ensure_capacity
    fired = {"n": 0}

    def flaky(slot, n_tokens):
        if fired["n"] == 0:
            fired["n"] += 1
            raise OutOfBlocks("injected decode-time exhaustion")
        return real(slot, n_tokens)

    monkeypatch.setattr(eng.kv, "ensure_capacity", flaky)
    sched.step()                       # preempts the mid-prefill burst
    assert fired["n"] == 1
    assert sched.preemptions == 1 and not sched.prefilling
    assert not eng._inflight
    sched.run()
    np.testing.assert_array_equal(sched.output(r_long), oracle[0])
    np.testing.assert_array_equal(sched.output(r_burst), oracle[1])
    _assert_no_leaks(sched)


def test_mid_prefill_preemption_resumes_from_prefix_cache(qwen):
    """A preempted mid-prefill request releases its pins and re-probes
    on resume — hitting whatever prefix its siblings cached meanwhile
    instead of recomputing from scratch."""
    cfg, _ = qwen
    rng = np.random.default_rng(3)
    shared = _prompt(rng, cfg, 16)                # two full KV blocks
    p_a = np.concatenate([shared, _prompt(rng, cfg, 7)])    # 3 blocks
    p_b = np.concatenate([shared, _prompt(rng, cfg, 21)])   # 5 blocks
    oracle = _dense_oracle(qwen, [p_a, p_b], [12, 2])
    # A (3 blocks) + B (5) fit an 8-block pool exactly; A's growth past
    # pos 24 two decode steps after B's admission forces the preemption
    # while B (21 uncached tokens at 4/step) is still mid-prefill
    eng = _engine(qwen, paged=True, num_blocks=8, chunk=4,
                  prefix_cache_blocks=16)
    sched = Scheduler(eng, prefill_token_budget=8)
    r_a = sched.submit(Request(p_a, SamplingParams(max_new_tokens=12,
                                                   greedy=True)))
    while not sched.active:                        # A prefilled + inserted
        sched.step()
    r_b = sched.submit(Request(p_b, SamplingParams(max_new_tokens=2,
                                                   greedy=True)))
    while sched.has_work and not sched.preemptions:
        sched.step()
    assert sched.preemptions >= 1
    # the victim is B, caught mid-prefill: back at the queue head with
    # no first token ever sampled and its cursor cancelled
    st_b = next(st for st in sched.queue if st.rid == r_b)
    assert st_b.emitted == [] and st_b.slot == -1
    assert st_b.slot not in eng._inflight
    # its first admission was warm (16 cached tokens recorded once)
    assert sched.metrics.cached_tokens_served >= 16
    cached0 = eng.cached_prefix_tokens
    sched.run()
    # the resume re-admitted B through the prefix cache a second time
    assert eng.cached_prefix_tokens >= cached0 + 16
    np.testing.assert_array_equal(sched.output(r_a), oracle[0])
    np.testing.assert_array_equal(sched.output(r_b), oracle[1])
    _assert_no_leaks(sched)


def test_advance_error_requeues_inflight_with_pins_released(qwen,
                                                            monkeypatch):
    """An engine error inside a prefill round (device OOM analogue)
    cancels every in-flight cursor, re-queues the requests with pins
    released, and leaves the scheduler consistent enough to retry."""
    cfg, _ = qwen
    rng = np.random.default_rng(4)
    prompts = [_prompt(rng, cfg, n) for n in (18, 25)]
    oracle = _dense_oracle(qwen, prompts, [3, 3])
    eng = _engine(qwen, paged=True, prefix_cache_blocks=16)
    sched = Scheduler(eng, prefill_token_budget=8)
    rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=3,
                                                   greedy=True)))
            for p in prompts]
    sched.step()
    assert sched.prefilling
    boom = RuntimeError("injected device failure")
    real = eng._prefill_paged

    def flaky(*a, **kw):
        monkeypatch.setattr(eng, "_prefill_paged", real)   # fail once
        raise boom

    monkeypatch.setattr(eng, "_prefill_paged", flaky)
    with pytest.raises(RuntimeError, match="injected device failure"):
        sched.step()
    assert not sched.prefilling and not eng._inflight
    assert eng.kv.pool.in_use == 0                 # slots + blocks released
    assert len(sched.queue) == len(rids)           # nobody lost
    sched.run()                                    # retry succeeds
    for r, ref in zip(rids, oracle):
        np.testing.assert_array_equal(sched.output(r), ref)
    _assert_no_leaks(sched)


def test_gateway_drain_completes_inflight_prefills(qwen):
    """Graceful drain keeps stepping until in-flight prefills finish:
    a request suspended mid-prompt when admission closes still
    completes, and `load` counts it while it is in flight."""
    cfg, _ = qwen
    rng = np.random.default_rng(5)
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, paged=True)], prefill_token_budget=8)
    h1 = gw.submit(Request(_prompt(rng, cfg, 4),
                           SamplingParams(max_new_tokens=4, greedy=True)))
    gw.step()
    h2 = gw.submit(Request(_prompt(rng, cfg, 33),
                           SamplingParams(max_new_tokens=2, greedy=True)))
    gw.step()
    sched = gw.replicas[0].scheduler
    assert sched.prefilling and sched.load >= 2
    gw.drain()
    assert len(gw.result(h1)) == 4 and len(gw.result(h2)) == 2
    _assert_no_leaks(sched)


# ---------------------------------------------------------------------------
# telemetry: jitter percentiles, budget utilization, gateway merge
# ---------------------------------------------------------------------------

def test_decode_gap_jitter_and_budget_metrics(qwen):
    t = [0.0]

    def clock():
        t[0] += 0.25
        return t[0]

    cfg, _ = qwen
    eng = _engine(qwen, paged=True)
    sched = Scheduler(eng, clock=clock, prefill_token_budget=16)
    rng = np.random.default_rng(6)
    for n, m in ((4, 6), (21, 2)):
        sched.submit(Request(_prompt(rng, cfg, n),
                             SamplingParams(max_new_tokens=m, greedy=True)))
    sched.run()
    s = sched.metrics.summary()
    dg = s["decode_gap_ms"]
    assert dg["count"] == s["decode_steps"] - 1
    assert dg["max"] >= dg["p95"] >= dg["p50"] > 0
    pb = s["prefill_budget"]
    assert pb["rounds"] > 0 and pb["tokens_executed"] > 0
    assert pb["utilization"] > 0


def test_merge_carries_jitter_without_zero_decode_double_count(qwen):
    """The satellite fix: a replica that decoded nothing reports gap
    count 0 and must not dilute (or zero out) the merged percentiles
    or the budget utilization."""
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    sched = Scheduler(_engine(qwen), clock=clock, prefill_token_budget=16)
    rid = sched.submit(Request(np.array([1, 2, 3], np.int32),
                               SamplingParams(max_new_tokens=4,
                                              greedy=True)))
    sched.run()
    busy = sched.metrics.summary()
    idle = ServingMetrics(clock=clock).summary()   # zero decode steps
    assert idle["decode_gap_ms"]["count"] == 0
    merged = merge_summaries([busy, idle])
    assert merged["decode_gap_ms"] == busy["decode_gap_ms"]
    assert merged["prefill_budget"]["utilization"] == \
        busy["prefill_budget"]["utilization"]
    # order must not matter either
    merged2 = merge_summaries([idle, busy])
    assert merged2["decode_gap_ms"] == merged["decode_gap_ms"]
    _ = rid


# ---------------------------------------------------------------------------
# invariant stress harness: random workloads vs the dense oracle
# ---------------------------------------------------------------------------

def _stress_case(qwen, seed: int, budget, num_blocks: int, n_req: int,
                 share_prefix: bool):
    """One randomized workload: mixed prompt lengths (optionally with a
    shared prefix to exercise pins), an undersized paged pool for
    preemption pressure, and a token budget.  Asserts the full
    invariant triad + bit-identical greedy outputs vs the
    **same-layout wave-at-once oracle** (budget None): interleaving
    changes when chunks run, never what they compute, so this must be
    exact whatever the workload.  The dense-layout comparison lives in
    the curated tests above instead — random workloads can land on
    near-tie logits where the paged kernel's page-wise online softmax
    legitimately flips an argmax against the dense path by float
    summation order (a pre-existing kernel/layout property this harness
    surfaced, not a scheduler defect)."""
    cfg, _ = qwen
    rng = np.random.default_rng(seed)
    shared = _prompt(rng, cfg, 8) if share_prefix else np.empty(0, np.int32)
    prompts, max_news = [], []
    for _i in range(n_req):
        tail = _prompt(rng, cfg, int(rng.integers(1, 21)))
        p = np.concatenate([shared, tail]).astype(np.int32)
        m = int(rng.integers(1, 9))
        if len(p) + m > 40:                        # keep admissible
            p = p[:40 - m]
        prompts.append(p)
        max_news.append(m)

    def serve(token_budget):
        eng = _engine(qwen, paged=True, num_blocks=num_blocks,
                      prefix_cache_blocks=12)
        sched = Scheduler(eng, prefill_token_budget=token_budget)
        rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=m,
                                                       greedy=True)))
                for p, m in zip(prompts, max_news)]
        sched.run()
        return [sched.output(r) for r in rids], sched

    oracle, osched = serve(None)                   # wave-at-once
    outs, sched = serve(budget)
    for out, ref in zip(outs, oracle):
        np.testing.assert_array_equal(out, ref)
    assert sched.metrics.summary()["requests_completed"] == n_req
    _assert_no_leaks(sched)
    _assert_no_leaks(osched)
    return sched


def test_interleaved_stress_quick(qwen):
    """Tier-1 depth: a couple of adversarial configurations, including
    an undersized pool that forces preemption."""
    sched = _stress_case(qwen, seed=10, budget=8, num_blocks=7, n_req=6,
                         share_prefix=True)
    assert sched.preemptions + sched.admission_stalls > 0, \
        "stress config no longer exercises the OutOfBlocks paths"
    _stress_case(qwen, seed=11, budget=16, num_blocks=18, n_req=5,
                 share_prefix=False)


@pytest.mark.slow
def test_interleaved_stress_deep(qwen):
    """CI depth (deselect with `-m "not slow"`): a seeded sweep over
    budgets x pool sizes x workload shapes."""
    for seed, budget, blocks, n_req, share in (
            (20, 8, 7, 7, True),        # starved pool: preemption + stalls
            (21, 24, 9, 6, False),      # mid budget, no sharing
            (23, 8, 18, 8, True)):      # ample pool: max concurrency mix
        _stress_case(qwen, seed=seed, budget=budget, num_blocks=blocks,
                     n_req=n_req, share_prefix=share)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       budget=st.integers(min_value=1, max_value=64),
       num_blocks=st.integers(min_value=6, max_value=18),
       n_req=st.integers(min_value=1, max_value=8))
def test_interleaved_stress_property(seed, budget, num_blocks, n_req):
    """Hypothesis-driven form of the same harness (skipped when
    hypothesis is absent from the capsule image): any admissible
    workload retires every request with bit-identical greedy output
    and zero leaked pins / slots / blocks."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    _stress_case((cfg, params), seed=seed, budget=budget,
                 num_blocks=num_blocks, n_req=n_req,
                 share_prefix=seed % 2 == 0)
