"""System-level model tests: decode==forward consistency per family,
long-context pattern behavior, loss shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def _mk(family, **kw):
    base = dict(name=f"t-{family}", family=family, num_layers=2, d_model=48,
                num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=61,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


FAMILY_CFGS = {
    "dense": _mk("dense"),
    "gemma2": _mk("dense", local_global_pattern=True, sliding_window=4,
                  attn_logit_softcap=50.0, final_logit_softcap=30.0,
                  post_block_norm=True, embed_scale=True),
    "moe": _mk("moe", num_experts=4, num_experts_per_tok=2,
               moe_capacity_factor=8.0),
    "ssm": _mk("ssm", num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=8,
               ssm_head_dim=16, ssm_chunk=8),
    "hybrid": _mk("hybrid", num_layers=3, hybrid_attn_every=1, ssm_state=8,
                  ssm_head_dim=16, ssm_chunk=8),
    "encdec": _mk("encdec", encoder_layers=2, encoder_seq=6,
                  max_pos_embed=64, norm_type="layernorm", act="gelu"),
    "vlm": _mk("vlm", mrope=True, mrope_sections=(3, 2, 1), num_patches=4),
}


@pytest.mark.parametrize("name", sorted(FAMILY_CFGS))
def test_decode_matches_forward(name, rng_key):
    """Teacher-forced decode through the cache must reproduce the forward
    logits — the strongest end-to-end consistency check we have."""
    cfg = FAMILY_CFGS[name]
    params = T.init_params(cfg, rng_key)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["encoder_input"] = jax.random.normal(
            jax.random.fold_in(rng_key, 2), (B, cfg.encoder_seq, cfg.d_model),
            jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeddings"] = jax.random.normal(
            jax.random.fold_in(rng_key, 3), (B, cfg.num_patches, cfg.d_model),
            jnp.float32) * 0.1
        Sfull = S + cfg.num_patches
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(Sfull)[None, None], (3, B, Sfull))
    logits_fwd, _ = T.forward(params, cfg, batch)

    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after a patch prefill; covered by "
                    "smoke decode test")
    cache = T.init_cache(cfg, B, S + 4)
    if cfg.family == "encdec":
        # decode consumes the ENCODED output, not the raw frames
        enc_out = T._encode(params["encoder"], cfg, batch["encoder_input"])
    outs = []
    for t in range(S):
        db = {"tokens": toks[:, t:t + 1],
              "positions": jnp.full((B,), t, jnp.int32), "cache": cache}
        if cfg.family == "encdec":
            db["encoder_output"] = enc_out
        lg, cache = T.decode_step(params, cfg, db)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), atol=2e-3)


def test_local_global_pattern_differs_from_global_only(rng_key):
    """Same params, window 4 vs window >= S (effectively global): positions
    inside the window agree, later positions diverge."""
    cfg = FAMILY_CFGS["gemma2"]                     # sliding_window=4
    params = T.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (1, 16), 0, cfg.vocab_size)
    l1, _ = T.forward(params, cfg, {"tokens": toks})
    cfg_g = cfg.with_(sliding_window=16)            # window covers all of S
    l2, _ = T.forward(params, cfg_g, {"tokens": toks})
    assert np.allclose(np.asarray(l1[:, :4]), np.asarray(l2[:, :4]), atol=1e-4)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                           atol=1e-3)


def test_long_context_window_activates(rng_key):
    cfg = _mk("dense", long_context_window=4)
    params = T.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (1, 16), 0, cfg.vocab_size)
    l_full, _ = T.forward(params, cfg, {"tokens": toks}, long_context=False)
    l_win, _ = T.forward(params, cfg, {"tokens": toks}, long_context=True)
    assert not np.allclose(np.asarray(l_full[:, -1]), np.asarray(l_win[:, -1]),
                           atol=1e-3)


def test_final_softcap_bounds_logits(rng_key):
    cfg = _mk("dense", final_logit_softcap=5.0)
    params = T.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (1, 8), 0, cfg.vocab_size)
    logits, _ = T.forward(params, cfg, {"tokens": toks})
    assert np.abs(np.asarray(logits)).max() <= 5.0 + 1e-5


def test_lm_loss_shifts_labels(rng_key):
    """Loss must compare logits[t] with labels[t+1]: feeding labels equal to
    a shifted copy of a learnable pattern must beat random labels."""
    cfg = _mk("dense")
    params = T.init_params(cfg, rng_key)
    toks = jnp.tile(jnp.arange(8)[None], (4, 1))
    loss_same, _ = T.lm_loss(params, cfg, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss_same))


def test_last_only_prefill_matches_full(rng_key):
    cfg = _mk("dense")
    params = T.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    last, _ = T.forward(params, cfg, {"tokens": toks}, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_chunked_ce_matches_plain(rng_key):
    """lm_loss_chunked (fused CE, §Perf optimization) must equal lm_loss in
    value AND gradient."""
    cfg = _mk("dense")
    params = T.init_params(cfg, rng_key)
    batch = {"tokens": jax.random.randint(rng_key, (2, 33), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng_key, (2, 33), 0, cfg.vocab_size)}
    l1, _ = T.lm_loss(params, cfg, batch)
    l2, _ = T.lm_loss_chunked(params, cfg, batch, seq_chunk=8)
    assert abs(float(l1) - float(l2)) < 1e-4
    g1 = jax.grad(lambda p: T.lm_loss(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: T.lm_loss_chunked(p, cfg, batch, seq_chunk=8)[0])(params)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-4


def test_int8_kv_cache_decode_accuracy(rng_key):
    """int8 KV cache (§Perf B3): decode logits within ~2% of f32 forward."""
    cfg = _mk("dense")
    params = T.init_params(cfg, rng_key)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (B, S), 0,
                              cfg.vocab_size)
    fwd, _ = T.forward(params, cfg, {"tokens": toks})
    cfg8 = cfg.with_(kv_cache_dtype="int8")
    cache = T.init_cache(cfg8, B, S + 4)
    assert cache["layers"]["k"].dtype == jnp.int8
    outs = []
    for t in range(S):
        lg, cache = T.decode_step(
            params, cfg8, {"tokens": toks[:, t:t + 1],
                           "positions": jnp.full((B,), t, jnp.int32),
                           "cache": cache})
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = (np.abs(np.asarray(dec) - np.asarray(fwd)).max()
           / np.abs(np.asarray(fwd)).max())
    assert rel < 0.03, rel
