"""Example scripts and launchers run end-to-end (subprocess integration)."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=540):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, env=env, cwd="/root/repo",
                          timeout=timeout)


@pytest.mark.slow
def test_quickstart():
    r = _run(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DOWN" in r.stdout
    assert "capsule run complete" in r.stdout


@pytest.mark.slow
def test_deploy_supermuc():
    r = _run(["examples/deploy_supermuc.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "BROKEN tensorflow" in r.stdout
    assert "charliecloud: ADMITTED" in r.stdout
    assert "mpiexec -n 32" in r.stdout


@pytest.mark.slow
def test_train_launcher_smoke():
    r = _run(["-m", "repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
              "--steps", "8", "--seq-len", "64", "--global-batch", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


@pytest.mark.slow
def test_serve_launcher_smoke():
    r = _run(["-m", "repro.launch.serve", "--arch", "mamba2-1.3b", "--smoke",
              "--requests", "2", "--max-new", "4", "--greedy"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_train_lm_example_short():
    r = _run(["examples/train_lm.py", "--model", "tiny", "--steps", "25",
              "--seq-len", "64", "--batch", "8", "--ckpt-every", "0"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DOWN" in r.stdout
