"""Serving observatory: per-tenant SLO monitoring, bounded percentile
windows, step/kernel profiling, recompilation telemetry, and the
trace_report SLO/profile sections.

Unit layers (SlidingWindow / TenantStats / SLOMonitor /
RecompilationTracker / StepProfiler) run against injected clocks; the
end-to-end tests drive real scheduler runs on the smoke model and pin
the contracts the benchmark relies on: tenant labels thread
submit -> scheduler -> summary -> merge, breach transitions land in the
trace as valid events, profiling is inert on outputs, and steady-state
serving never recompiles post-warm while injected shape churn does.
"""
import json

import jax
import numpy as np
import pytest

from repro.serving import (RecompilationTracker, Request, SamplingParams,
                           Scheduler, ServingEngine, ServingMetrics,
                           SLOConfig, SLOMonitor, SLOPolicy, SlidingWindow,
                           StepProfiler, TenantStats, Tracer,
                           atomic_write_json, merge_summaries,
                           merge_window_summaries, validate_event)
from repro.serving.metrics import _pct


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, *, slots=3, seq=48, block=8, chunk=8, prefill_batch=2,
            **kw):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         kv_block_size=block, prefill_chunk=chunk,
                         prefill_batch=prefill_batch, **kw)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _trace_report():
    import importlib
    import sys
    from pathlib import Path
    scripts = str(Path(__file__).resolve().parents[1] / "scripts")
    if scripts not in sys.path:
        sys.path.insert(0, scripts)
    return importlib.import_module("trace_report")


def _ticker(dt=1.0):
    t = [0.0]

    def clock():
        t[0] += dt
        return t[0]
    return clock


# ---------------------------------------------------------------------------
# SlidingWindow: bounded memory, exact small-N percentiles (satellite a)
# ---------------------------------------------------------------------------

def test_sliding_window_small_n_matches_exact_percentiles():
    """Below the cap the ring holds everything: percentiles must equal
    the unbounded ``_pct`` over the full sample list, bit for bit."""
    w = SlidingWindow(window=64)
    xs = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0]
    for x in xs:
        w.add(x)
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 1.0):
        assert w.percentile(q) == _pct(xs, q)
    s = w.summary()
    assert s["count"] == len(xs)
    assert s["max"] == max(xs)
    assert s["mean"] == pytest.approx(sum(xs) / len(xs))


def test_sliding_window_caps_memory_but_keeps_totals_exact():
    w = SlidingWindow(window=16)
    n = 1000
    for i in range(n):
        w.add(float(i))
    assert len(w.ring) == 16                     # bounded
    assert w.count == n and w.peak == float(n - 1)
    assert w.mean == pytest.approx(sum(range(n)) / n)
    # percentiles are over the most recent 16 samples only
    assert w.percentile(0.5) == _pct([float(i) for i in range(n - 16, n)],
                                     0.5)
    with pytest.raises(ValueError, match="window"):
        SlidingWindow(window=0)


def test_merge_window_summaries_skips_empty_windows():
    busy = SlidingWindow(8)
    for x in (10.0, 20.0, 30.0):
        busy.add(x)
    idle = SlidingWindow(8)
    merged = merge_window_summaries([busy.summary(), idle.summary()])
    assert merged == busy.summary()              # idle contributed nothing
    assert merge_window_summaries([])["count"] == 0


# ---------------------------------------------------------------------------
# ServingMetrics: bounded per-request samples (satellite a)
# ---------------------------------------------------------------------------

def test_metrics_sample_cap_bounds_dicts_totals_stay_exact():
    m = ServingMetrics(clock=_ticker(), sample_cap=4)
    for rid in range(20):
        m.record_submit(rid)
        m.record_admit(rid)
        m.record_first_token(rid)
        m.record_finish(rid, 2, "length")
    # only the most recent 4 finished rids keep per-request entries
    assert len(m._submit) == 4 and len(m._finish) == 4
    assert set(m._finish) == {16, 17, 18, 19}
    # running totals never evicted
    s = m.summary()
    assert s["requests_completed"] == 20
    assert s["total_new_tokens"] == 40
    assert s["finish_reasons"] == {"length": 20}
    assert s["queue_wait_ms"]["count"] == 20     # window count is all-time
    with pytest.raises(ValueError, match="sample_cap"):
        ServingMetrics(sample_cap=0)


def test_metrics_below_cap_percentiles_unchanged_by_cap():
    """Small runs must see byte-identical numbers whatever the cap: the
    cap only changes behavior beyond ``sample_cap`` finished requests."""
    def run(cap):
        m = ServingMetrics(clock=_ticker(0.5), sample_cap=cap)
        for rid in range(6):
            m.record_submit(rid, tenant="t")
            m.record_admit(rid)
            m.record_first_token(rid)
            m.record_finish(rid, 3, "length")
        return m.summary()

    small, big = run(8), run(4096)
    assert small["ttft_ms"] == big["ttft_ms"]
    assert small["queue_wait_ms"] == big["queue_wait_ms"]
    assert small["tenants"] == big["tenants"]


def test_atomic_write_json_leaves_no_tmp(tmp_path):
    out = tmp_path / "nested" / "totals.json"
    p = atomic_write_json(out, {"a": 1, "path": tmp_path})
    assert p == out
    assert json.loads(out.read_text())["a"] == 1
    assert list(tmp_path.glob("**/*.tmp")) == []
    # overwrite is atomic too (same name, replaced content)
    atomic_write_json(out, {"a": 2})
    assert json.loads(out.read_text())["a"] == 2


# ---------------------------------------------------------------------------
# tenant threading + merge (satellite c)
# ---------------------------------------------------------------------------

def test_tenant_stats_thread_through_metrics():
    m = ServingMetrics(clock=_ticker())
    m.record_submit(0, tenant="a")
    m.record_submit(1, tenant="b")
    m.record_admit(0)
    m.record_admit(1)
    m.record_first_token(0)
    m.record_first_token(1)
    m.record_decode_tokens([0, 1])
    m.record_decode_tokens([0, 1])
    m.record_finish(0, 3, "length")
    m.record_finish(1, 3, "length")
    t = m.summary()["tenants"]
    assert set(t) == {"a", "b"}
    for name in ("a", "b"):
        assert t[name]["requests_completed"] == 1
        assert t[name]["ttft_ms"]["count"] == 1
        assert t[name]["queue_wait_ms"]["count"] == 1
        assert t[name]["decode_gap_ms"]["count"] == 2
        assert t[name]["ttft_ms"]["p95"] > 0


def test_merge_summaries_disjoint_tenants_pass_through():
    def mk(tenant):
        m = ServingMetrics(clock=_ticker())
        m.record_submit(0, tenant=tenant)
        m.record_admit(0)
        m.record_first_token(0)
        m.record_finish(0, 4, "length")
        return m.summary()

    sa, sb = mk("a"), mk("b")
    merged = merge_summaries([sa, sb])["tenants"]
    assert set(merged) == {"a", "b"}
    assert merged["a"] == sa["tenants"]["a"]     # disjoint: unchanged
    assert merged["b"] == sb["tenants"]["b"]


def test_merge_summaries_overlapping_tenants_merge_windows():
    def mk(ttft_dt):
        m = ServingMetrics(clock=_ticker(ttft_dt))
        m.record_submit(0, tenant="shared")
        m.record_admit(0)
        m.record_first_token(0)
        m.record_finish(0, 4, "length")
        return m.summary()

    fast, slow = mk(0.1), mk(0.9)
    merged = merge_summaries([fast, slow])["tenants"]["shared"]
    assert merged["requests_completed"] == 2
    assert merged["new_tokens"] == 8
    # percentile merge is the conservative max across replicas
    assert merged["ttft_ms"]["p95"] == pytest.approx(
        max(fast["tenants"]["shared"]["ttft_ms"]["p95"],
            slow["tenants"]["shared"]["ttft_ms"]["p95"]))
    assert merged["ttft_ms"]["count"] == 2


def test_zero_decode_replica_does_not_dilute_tenant_jitter():
    """PR 5 regression extended to tenants: an idle replica (zero decode
    gaps, zero tenant samples) must leave both the fleet jitter numbers
    and the per-tenant windows of the busy replica exactly unchanged."""
    busy = ServingMetrics(clock=_ticker(0.25))
    busy.record_submit(0, tenant="t")
    busy.record_admit(0)
    busy.record_first_token(0)
    for _ in range(3):
        busy.record_decode_tokens([0])
        busy.sample_gauges(0, 1, 2)
    busy.record_finish(0, 4, "length")
    bs = busy.summary()
    idle = ServingMetrics(clock=lambda: 0.0).summary()
    merged = merge_summaries([bs, idle])
    assert merged["decode_gap_ms"] == bs["decode_gap_ms"]
    assert merged["tenants"]["t"]["decode_gap_ms"] == \
        bs["tenants"]["t"]["decode_gap_ms"]
    assert merged["tenants"]["t"]["ttft_ms"] == bs["tenants"]["t"]["ttft_ms"]


# ---------------------------------------------------------------------------
# SLO policies + monitor
# ---------------------------------------------------------------------------

def test_slo_config_json_roundtrip_and_unknown_key_rejection(tmp_path):
    doc = {"default": {"ttft_p95_ms": 500.0, "min_samples": 4},
           "tenants": {"premium": {"ttft_p95_ms": 200.0,
                                   "min_tokens_per_s": 10.0}}}
    path = tmp_path / "slo.json"
    path.write_text(json.dumps(doc))
    cfg = SLOConfig.from_json(path)
    assert cfg.default.ttft_p95_ms == 500.0
    assert cfg.default.min_samples == 4
    assert cfg.policy_for("premium").ttft_p95_ms == 200.0
    assert cfg.policy_for("premium").min_tokens_per_s == 10.0
    assert cfg.policy_for("anyone-else") is cfg.default
    # roundtrip through to_dict parses back to the same policies
    again = SLOConfig.from_dict(cfg.to_dict())
    assert again.default == cfg.default
    assert again.tenants == cfg.tenants
    with pytest.raises(ValueError, match="unknown SLO policy keys"):
        SLOPolicy.from_dict({"ttft_p95": 1.0})   # typo'd key fails loudly


def _stats_with(ttft_ms_samples, completed=0, tokens=0, span=None):
    ts = TenantStats()
    for x in ttft_ms_samples:
        ts.ttft_ms.add(x)
    ts.completed = completed
    ts.new_tokens = tokens
    if span is not None:
        ts.first_submit_ts, ts.last_finish_ts = 0.0, span
    return ts


def test_slo_monitor_edge_triggered_breach_and_recovery():
    cfg = SLOConfig(SLOPolicy(ttft_p95_ms=100.0, min_samples=2))
    mon = SLOMonitor(cfg)
    bad = {"t": _stats_with([150.0, 160.0])}
    trans = mon.evaluate(bad)
    assert len(trans) == 1 and trans[0]["recovered"] is False
    assert trans[0]["metric"] == "ttft_p95_ms"
    assert mon.breaches == 1
    # sustained breach: no new transition, no new count
    assert mon.evaluate(bad) == []
    assert mon.breaches == 1
    assert mon.active_breaches() == [{"tenant": "t",
                                      "metric": "ttft_p95_ms"}]
    # recovery is one transition with the flag set
    good = {"t": _stats_with([150.0, 160.0] + [10.0] * 30)}
    trans = mon.evaluate(good)
    assert len(trans) == 1 and trans[0]["recovered"] is True
    assert mon.breaches == 1                      # recoveries don't count
    assert mon.active_breaches() == []
    assert mon.summary()["breaches"] == 1


def test_slo_monitor_min_samples_gates_verdicts():
    mon = SLOMonitor(SLOConfig(SLOPolicy(ttft_p95_ms=1.0, min_samples=8)))
    thin = {"t": _stats_with([999.0] * 7)}        # breach-worthy but thin
    assert mon.evaluate(thin) == []
    thin["t"].ttft_ms.add(999.0)                  # 8th sample: verdict
    assert len(mon.evaluate(thin)) == 1


def test_slo_monitor_throughput_lower_bound():
    pol = SLOPolicy(min_tokens_per_s=100.0, min_samples=1)
    mon = SLOMonitor(SLOConfig(pol))
    slow = {"t": _stats_with([], completed=2, tokens=10, span=1.0)}
    trans = mon.evaluate(slow)
    assert len(trans) == 1 and trans[0]["metric"] == "min_tokens_per_s"
    fast = {"t": _stats_with([], completed=2, tokens=1000, span=1.0)}
    assert mon.evaluate(fast)[0]["recovered"] is True


# ---------------------------------------------------------------------------
# recompilation telemetry
# ---------------------------------------------------------------------------

def test_recompilation_tracker_counts_and_warm_semantics():
    rt = RecompilationTracker()
    assert rt.observe("decode", ((4,), (4,))) is True    # first compile
    assert rt.observe("decode", ((4,), (4,))) is False   # cache hit
    assert rt.observe("decode", ((5,), (5,))) is True    # second signature
    assert rt.compiles("decode") == 2 and rt.compiles() == 2
    assert rt.post_warm_recompiles == 0                  # not warm yet
    rt.mark_warm()
    assert rt.observe("decode", ((6,), (6,))) is True
    assert rt.post_warm_recompiles == 1
    s = rt.summary()
    assert s["warm"] and s["compiles_total"] == 3
    assert s["programs"]["decode"] == {"signatures": 3, "post_warm": 1}
    assert "decode" in s["churning"]


def test_recompile_warnings_reach_the_tracer():
    rt = RecompilationTracker()
    tr = Tracer(enabled=True, clock=_ticker())
    rt.observe("p", (1,), tracer=tr)          # first signature: silent
    assert [e["kind"] for e in tr.snapshot()] == []
    rt.observe("p", (2,), tracer=tr)          # churn before warm: warns
    rt.mark_warm()
    rt.observe("q", (1,), tracer=tr)          # post-warm novelty: warns
    evs = tr.snapshot()
    assert [e["kind"] for e in evs] == ["recompile", "recompile"]
    assert evs[0]["post_warm"] is False and evs[1]["post_warm"] is True
    for ev in evs:
        assert validate_event(ev) is None


def test_steady_state_zero_postwarm_then_injected_churn_warns(qwen):
    """The benchmark's recompile contract as a test: replaying the same
    workload after ``mark_warm`` must be signature-stable, and a decode
    batch whose padding wobbles must raise the counter AND emit tracer
    warnings."""
    cfg, _ = qwen
    eng = _engine(qwen, paged=True)
    rng = np.random.default_rng(11)
    prompts = [_prompt(rng, cfg, n) for n in (5, 17, 9)]

    def serve():
        sched = Scheduler(eng, tracer=Tracer())
        for p in prompts:
            sched.submit(Request(p, SamplingParams(max_new_tokens=3,
                                                   greedy=True)))
        sched.run()

    serve()
    assert eng.recompiles.compiles() > 0
    eng.recompiles.mark_warm()
    serve()                                    # steady state: same shapes
    assert eng.recompiles.post_warm_recompiles == 0, (
        f"replaying an identical workload recompiled: "
        f"{eng.recompiles.summary()}")
    # inject the classic variable-batch bug: sample batches sized past
    # anything serving produced (> max_slots rows) genuinely recompile
    eng.tracer = Tracer(enabled=True)
    V = cfg.vocab_size
    for k in (4, 5):                           # max_slots is 3
        eng.sample_tokens(np.zeros((k, V), np.float32),
                          np.zeros(k, np.float32), np.ones(k, bool))
    assert eng.recompiles.post_warm_recompiles >= 2
    warns = [e for e in eng.tracer.snapshot() if e["kind"] == "recompile"]
    assert len(warns) >= 2
    assert all(w["program"] == "sample" and w["post_warm"] for w in warns)
    assert "sample" in eng.recompiles.churning_programs()


# ---------------------------------------------------------------------------
# step profiler
# ---------------------------------------------------------------------------

def test_step_profiler_windows():
    prof = StepProfiler(window=4)
    for i in range(10):
        prof.record_step(0.001, 0.002 * i, 0.003, 0.0)
    s = prof.summary()
    assert s["steps"] == 10
    assert s["admit_ms"]["count"] == 10
    assert s["admit_ms"]["p50"] == pytest.approx(1.0)
    assert s["prefill_ms"]["max"] == pytest.approx(18.0)
    assert s["sample_ms"]["p95"] == 0.0


def test_profiling_populates_phases_and_is_inert_on_outputs(qwen):
    cfg, _ = qwen
    rng = np.random.default_rng(12)
    prompts = [_prompt(rng, cfg, n) for n in (7, 13)]
    eng = _engine(qwen, paged=True)

    def serve(profile):
        sched = Scheduler(eng, tracer=Tracer(), profile=profile)
        rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=3,
                                                       greedy=True)))
                for p in prompts]
        sched.run()
        return [sched.output(r) for r in rids], sched.profiler

    plain_out, none_prof = serve(False)
    prof_out, prof = serve(True)
    assert none_prof is None
    for a, b in zip(plain_out, prof_out):
        np.testing.assert_array_equal(a, b)    # profiling is inert
    s = prof.summary()
    assert s["steps"] > 0
    for phase in ("admit", "prefill", "decode", "sample"):
        st = s[f"{phase}_ms"]
        assert st["count"] == s["steps"]
        assert st["max"] >= 0.0
    # the decode phase of a real run takes measurable device time
    assert s["decode_ms"]["max"] > 0.0


def test_profile_paged_kernels_structure(qwen):
    from repro.serving import profile_paged_kernels
    eng = _engine(qwen, paged=True)
    profs = profile_paged_kernels(eng, reps=1)
    assert set(profs) == {"paged_attention", "paged_prefill"}
    for prof in profs.values():
        assert prof["wall_ms_median"] > 0.0
        assert prof["flops"] > 0.0
        assert prof["bytes_accessed"] > 0.0
        assert prof["arithmetic_intensity"] > 0.0
        assert prof["fraction_of_peak_flops"] >= 0.0
    with pytest.raises(ValueError, match="paged"):
        profile_paged_kernels(_engine(qwen))   # dense engine refused


# ---------------------------------------------------------------------------
# end-to-end: tenants + SLO breaches through a real run, then the report
# ---------------------------------------------------------------------------

def test_observatory_end_to_end_and_trace_report(qwen, tmp_path, capsys):
    cfg, _ = qwen
    rng = np.random.default_rng(13)
    # impossible TTFT bound so the run provably breaches
    slo = SLOConfig.from_dict({
        "default": {"ttft_p95_ms": 1e9},
        "tenants": {"gold": {"ttft_p95_ms": 1e-6, "min_samples": 1}}})
    tracer = Tracer(enabled=True, slo=SLOMonitor(slo))
    sched = Scheduler(_engine(qwen, paged=True), tracer=tracer,
                      profile=True)
    for i in range(4):
        sched.submit(Request(
            _prompt(rng, cfg, int(rng.integers(5, 20))),
            SamplingParams(max_new_tokens=3, greedy=True),
            tenant="gold" if i % 2 == 0 else "basic"))
    sched.run()

    # tenant labels threaded end-to-end into the summary
    t = sched.metrics.summary()["tenants"]
    assert set(t) == {"gold", "basic"}
    assert sum(x["requests_completed"] for x in t.values()) == 4
    assert all(x["ttft_ms"]["count"] == 2 for x in t.values())
    assert all(x["queue_wait_ms"]["count"] == 2 for x in t.values())
    # only the tenant with the impossible policy breached
    assert tracer.slo.breaches >= 1
    assert {b["tenant"] for b in tracer.slo.active_breaches()} == {"gold"}
    breaches = [e for e in tracer.snapshot() if e["kind"] == "slo_breach"]
    assert breaches and all(validate_event(e) is None for e in breaches)
    assert all(e["tenant"] == "gold" for e in breaches)

    # the exported trace renders the SLO + profile report sections
    jsonl = tracer.export_jsonl(tmp_path / "obs.jsonl")
    trace_report = _trace_report()
    out_json = tmp_path / "report.json"
    rc = trace_report.main([str(jsonl), "--slo", "--profile",
                            "--validate", "--json", str(out_json)])
    assert rc == 0, capsys.readouterr().out
    data = json.loads(out_json.read_text())
    assert set(data["slo"]["tenants"]) == {"gold", "basic"}
    assert data["slo"]["breaches"]
    assert all(b["tenant"] == "gold" for b in data["slo"]["breaches"])
    assert set(data["profile"]["phases"]) == {"admit", "prefill",
                                              "decode", "sample"}
    assert data["requests"]["requests"]
    capsys.readouterr()                        # drain the report text


def test_trace_report_empty_sections_warn_and_fail_validate(tmp_path,
                                                            capsys):
    trace_report = _trace_report()
    # a schema-valid trace with engine steps but zero request spans
    path = tmp_path / "steps_only.jsonl"
    path.write_text(json.dumps({"ts": 0.0, "kind": "engine_step",
                                "step": 0}) + "\n")
    rc = trace_report.main([str(path)])
    out = capsys.readouterr().out
    assert rc == 0                              # warn-only by default
    assert "empty report section(s): requests" in out
    rc = trace_report.main([str(path), "--validate"])
    out = capsys.readouterr().out
    assert rc == 1                              # CI mode fails
    assert "FAIL" in out and "requests" in out
    # requesting --slo on a tenant-less trace is an empty section too
    assert trace_report.main([str(path), "--slo", "--validate"]) != 0
    capsys.readouterr()


def test_serve_launcher_observatory_flags(qwen, tmp_path, capsys):
    """The CLI path (satellite b): --tenant/--slo-config/--profile/
    --metrics-out with periodic atomic flushes."""
    from repro.launch import serve
    slo_path = tmp_path / "slo.json"
    slo_path.write_text(json.dumps(
        {"default": {"ttft_p95_ms": 1e-6, "min_samples": 1}}))
    metrics = tmp_path / "totals.json"
    serve.main(["--arch", "qwen2-0.5b", "--smoke", "--requests", "3",
                "--max-new", "2", "--greedy", "--max-slots", "3",
                "--max-seq-len", "48", "--tenant", "a,b",
                "--slo-config", str(slo_path), "--profile",
                "--metrics-out", str(metrics),
                "--metrics-interval-steps", "1"])
    out = capsys.readouterr().out
    assert "tenant a:" in out and "tenant b:" in out
    assert "SLO [replica0]:" in out
    assert "profile [replica0]:" in out and "recompiles [replica0]:" in out
    totals = json.loads(metrics.read_text())
    assert totals["requests_completed"] == 3
    assert set(totals["tenants"]) == {"a", "b"}
    assert totals["slo_breaches"] >= 1
    assert list(tmp_path.glob("*.tmp")) == []   # atomic flushes cleaned up
