"""Distribution tests: hvd DP semantics, PS baseline equivalence, sharding
spec rules, pjit step on a multi-device host mesh (subprocess)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed import sharding as sh


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})


def test_fit_spec_drops_indivisible_axes():
    s = sh.fit_spec(P(None, "model", None), (24, 2, 64), MESH)
    assert tuple(s) == (None, None, None)
    s = sh.fit_spec(P("data", "model"), (32, 32), MESH)
    assert tuple(s) == ("data", "model")
    s = sh.fit_spec(P(("data", "model"), None), (256, 4), MESH)
    assert tuple(s)[0] == ("data", "model")    # 256 = 16*16 fits both
    s = sh.fit_spec(P(("data", "model"), None), (16, 4), MESH)
    assert tuple(s)[0] == "data"               # 16 fits data, not data*model


def test_param_specs_megatron_rules():
    cfg = get_config("qwen2-vl-72b")           # 64 heads: divisible by 16
    # wq (stacked: G, pat, d, h, dh): heads sharded over model, d over data
    s = sh.param_spec("layers/attn/wq/w", (80, 1, 8192, 64, 128), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "data", "model", None)
    # wo row-parallel
    s = sh.param_spec("layers/attn/wo/w", (80, 1, 8192, 8192), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "model", "data")
    # mlp column parallel
    s = sh.param_spec("layers/mlp/wi/w", (80, 1, 8192, 29568), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "data", "model")
    # dp_tp drops the fsdp axis
    s = sh.param_spec("layers/mlp/wi/w", (80, 1, 8192, 29568), cfg,
                      "dp_tp", MESH)
    assert tuple(s) == (None, None, None, "model")
    # dp replicates everything
    s = sh.param_spec("layers/mlp/wi/w", (80, 1, 8192, 29568), cfg, "dp",
                      MESH)
    assert tuple(s) == ()


def test_param_specs_indivisible_heads_fall_back():
    """deepseek-coder has 56 heads (not divisible by 16): attention weights
    drop the 'model' axis (documented fallback; MLP/embed stay TP)."""
    cfg = get_config("deepseek-coder-33b")
    s = sh.param_spec("layers/attn/wq/w", (62, 1, 7168, 56, 128), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "data", None, None)
    s = sh.param_spec("layers/mlp/wi/w", (62, 1, 7168, 19200), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "data", "model")


def test_moe_expert_parallel_spec():
    cfg = get_config("dbrx-132b")
    s = sh.param_spec("layers/moe/wi", (40, 1, 16, 6144, 10752), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "model", "data", None)
    s = sh.param_spec("layers/moe/wo", (40, 1, 16, 10752, 6144), cfg,
                      "fsdp_tp", MESH)
    assert tuple(s) == (None, None, "model", None, "data")


def test_hvd_and_ps_same_trajectory_multi_device():
    """Run 3 steps of hvd-DP and PS-DP on an 8-device host in a subprocess;
    trajectories must match to ~1e-4 (same math, different collectives)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.models import transformer as T
        from repro.core import hvd, paramserver
        from repro.launch.mesh import make_mesh
        from repro import optim
        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97)
        key = jax.random.PRNGKey(0)
        mesh = make_mesh((8,), ("data",))
        opt = optim.rmsprop(1e-3)
        loss_fn = lambda p, b: T.lm_loss(p, cfg, b)
        batch = {"tokens": jax.random.randint(key, (16, 16), 0, 97),
                 "labels": jax.random.randint(key, (16, 16), 0, 97)}
        out = {}
        for name, maker in [("hvd", hvd.make_train_step),
                            ("ps", paramserver.make_train_step)]:
            params = T.init_params(cfg, key)
            st = opt.init(params)
            step = maker(loss_fn, opt, mesh)
            ls = []
            for i in range(3):
                params, st, m = step(params, st, batch)
                ls.append(float(m["loss"]))
            out[name] = ls
        # single-device reference: same final loss => DP invariance
        params = T.init_params(cfg, key)
        st = opt.init(params)
        @jax.jit
        def sstep(p, s, b):
            (l, m), g = jax.value_and_grad(
                lambda p_: loss_fn(p_, b), has_aux=True)(p)
            u, s = opt.update(g, s, p)
            return optim.apply_updates(p, u), s, l
        ls = []
        for i in range(3):
            params, st, l = sstep(params, st, batch)
            ls.append(float(l))
        out["single"] = ls
        print("RESULT " + json.dumps(out))
    """)
    import os
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd="/root/repo", timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    np.testing.assert_allclose(out["hvd"], out["ps"], atol=1e-3)
    np.testing.assert_allclose(out["hvd"], out["single"], atol=1e-3)


def test_batch_pspec_decode_cache_layouts():
    from repro.configs.base import SHAPES, input_specs
    mesh = _FakeMesh({"data": 16, "model": 16})
    cfg = get_config("dbrx-132b")                   # kv=8: seq-sharded cache
    bspec = sh.batch_pspec(input_specs(cfg, SHAPES["decode_32k"]), mesh, cfg,
                           SHAPES["decode_32k"])
    kspec = tuple(bspec["cache"]["layers"]["k"])
    assert kspec[-3] == "model" or "model" in (kspec[-3],), kspec  # seq dim
    cfg2 = get_config("gemma2-27b")                 # kv=16: head-sharded
    bspec2 = sh.batch_pspec(input_specs(cfg2, SHAPES["decode_32k"]), mesh,
                            cfg2, SHAPES["decode_32k"])
    assert tuple(bspec2["cache"]["layers"]["k"])[-2] == "model"


def test_hierarchical_allreduce_equivalence_and_interpod_traffic():
    """Beyond-paper pod-aware allreduce: bit-identical training, inter-pod
    bytes cut by ~|inner axes| (measured from the compiled HLO)."""
    import textwrap
    prog = textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.models import transformer as T
        from repro.core import hvd
        from repro.launch.mesh import make_mesh
        from repro import optim
        from repro.launch.dryrun import collective_bytes_by_scope
        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97)
        key = jax.random.PRNGKey(0)
        mesh = make_mesh((2, 8), ("pod", "data"))
        opt = optim.rmsprop(1e-3)
        loss_fn = lambda p, b: T.lm_loss(p, cfg, b)
        params = T.init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (16, 32), 0, 97),
                 "labels": jax.random.randint(key, (16, 32), 0, 97)}
        out = {}
        for name, hier in [("flat", False), ("hier", True)]:
            p, s = params, opt.init(params)
            step = hvd.make_train_step(loss_fn, opt, mesh,
                                       axes=("pod", "data"),
                                       hierarchical=hier, donate=False)
            txt = step.lower(p, s, batch).compile().as_text()
            scope = collective_bytes_by_scope(txt, pod_size=8)
            for i in range(2):
                p, s, m = step(p, s, batch)
            out[name] = {"loss": float(m["loss"]), **scope}
        print("RESULT " + json.dumps(out))
    """)
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    env = dict(_os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = _sp.run([_sys.executable, "-c", prog], capture_output=True, text=True,
                env=env, cwd="/root/repo", timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = _json.loads(line[len("RESULT "):])
    assert abs(out["flat"]["loss"] - out["hier"]["loss"]) < 1e-5
    assert out["hier"]["inter_pod"] < 0.2 * out["flat"]["inter_pod"]


def test_gradient_accumulation_matches_full_batch():
    """microbatches=M must produce the same update as the full batch
    (token-mean CE; activation memory / M)."""
    import jax
    import jax.numpy as jnp
    from repro import optim
    from repro.configs import get_smoke_config
    from repro.configs.base import InputShape
    from repro.data import SyntheticTokenSource, TokenDatasetSpec
    from repro.distributed import stepfn
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T

    cfg = get_smoke_config("qwen2-0.5b").with_(dtype="float32")
    mesh = make_host_mesh()
    shape = InputShape("t", 64, 8, "train")
    opt = optim.adamw(1e-3)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    src = SyntheticTokenSource(TokenDatasetSpec(cfg.vocab_size, 64, 8))
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
    outs = {}
    for mb in (1, 4):
        step, _, _ = stepfn.make_train_step(cfg, opt, mesh, "dp", shape,
                                            microbatches=mb)
        fresh = jax.tree.map(jnp.copy, params)   # step donates its inputs
        p, st, m = step(fresh, opt.init(fresh), batch)
        outs[mb] = (float(m["loss"]), p)
    assert abs(outs[1][0] - outs[4][0]) < 1e-5
    err = max(float(jnp.abs(a.astype(jnp.float32)
                            - b.astype(jnp.float32)).max())
              for a, b in zip(jax.tree.leaves(outs[1][1]),
                              jax.tree.leaves(outs[4][1])))
    # f32 summation order differs between one fused batch and 4 accumulated
    # microbatches; the adamw-normalized update bounds the drift at ~1e-5
    assert err < 5e-5
