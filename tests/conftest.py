import os

# smoke tests and benches must see ONE device; only launch/dryrun.py (its own
# process) sets xla_force_host_platform_device_count.  Tests that need a
# multi-device host mesh spawn subprocesses or use their own env (see
# test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (dry-run compiles)")
