"""Optional-hypothesis shim.

The secure-cluster image cannot ``pip install`` extras (the whole point of
the paper), so ``hypothesis`` may be absent.  Test modules import ``given``,
``settings`` and ``st`` from here: with hypothesis installed they get the
real thing; without it the property tests are marked skipped at decoration
time and every other test in the module still collects and runs.
"""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                   # pragma: no cover
    import pytest

    def settings(**_kw):
        return lambda fn: fn

    def given(*_a, **_kw):
        def deco(fn):
            @pytest.mark.skip(
                reason="hypothesis not installed (property test)")
            def shim():
                pass
            shim.__name__ = fn.__name__
            shim.__doc__ = fn.__doc__
            return shim
        return deco

    class _Strategy:
        """Inert placeholder so strategy expressions at decoration time
        (st.integers(...), st.one_of(...)) evaluate without hypothesis."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _Strategy()

__all__ = ["given", "settings", "st"]
