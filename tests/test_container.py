"""Charliecloud-capsule workflow + site security policy tests."""
import os
from pathlib import Path

import pytest

from repro.core import container as C
from repro.core import deploy as D
from repro.core.registry import OfflineViolation, default_index


@pytest.fixture
def pipeline():
    return D.DeploymentPipeline(index=default_index())


def test_build_requires_workstation():
    builder = C.ImageBuilder(default_index(), context=C.CLUSTER)
    with pytest.raises(OfflineViolation):
        builder.build(C.ImageDefinition("x", requirements=("numpy>=1.14",)))


def test_full_pipeline_and_run(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t1"), tmp_path, nodes=4)
    assert dep.archive.exists() and dep.unpacked.exists()
    assert "mpiexec -n 4 -ppn 1 ch-run" in dep.slurm_script
    res = dep.run(lambda: os.environ["REPRO_CAPSULE"], ranks=3)
    assert [r.value for r in res] == ["t1"] * 3
    assert res[1].rank == 1 and res[1].world_size == 3


def test_env_scrubbed_and_restored(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t2"), tmp_path)
    os.environ["SSH_AUTH_SOCK"] = "/tmp/ssh-evil"
    try:
        res = dep.run(lambda: os.environ.get("SSH_AUTH_SOCK", "SCRUBBED"))
        assert res[0].value == "SCRUBBED"
        assert os.environ["SSH_AUTH_SOCK"] == "/tmp/ssh-evil"  # restored
    finally:
        del os.environ["SSH_AUTH_SOCK"]


def test_pip_inside_capsule_dies(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t3"), tmp_path)
    with pytest.raises(OfflineViolation):
        dep.run(lambda: C.capsule_pip_install("pandas"))


def test_image_immutability(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t4"), tmp_path)

    def vandalize():
        root = Path(os.environ["REPRO_CAPSULE_ROOT"])
        (root / "image" / "manifest.json").write_text("{}")
        return True

    with pytest.raises(C.SecurityError, match="immutability"):
        dep.run(vandalize)
    # with -w (writeable) it is allowed, like ch-run -w
    dep2 = pipeline.deploy(D.intel_tensorflow_image("t5"), tmp_path)
    dep2.runtime.run(dep2.unpacked, vandalize, writeable=True)


def test_unpack_refuses_hash_mismatch(tmp_path):
    idx = default_index()
    b = C.ImageBuilder(idx)
    img1 = b.build(C.ImageDefinition("same-name", requirements=("numpy>=1.14",)))
    img2 = b.build(C.ImageDefinition("same-name", requirements=("six>=1.10",)))
    a1 = C.flatten(img1, tmp_path / "w1")
    a2 = C.flatten(img2, tmp_path / "w2")
    C.unpack(a1, tmp_path / "tmpfs")
    with pytest.raises(C.SecurityError, match="hash mismatch"):
        C.unpack(a2, tmp_path / "tmpfs")


def test_site_policy_rejects_docker_singularity_admits_charliecloud():
    pol = C.SecurityPolicy()
    with pytest.raises(C.SecurityError):
        pol.admit(C.RUNTIME_PROFILES["docker"])
    with pytest.raises(C.SecurityError):
        pol.admit(C.RUNTIME_PROFILES["singularity"])
    pol.admit(C.RUNTIME_PROFILES["charliecloud"])  # no raise


def test_slurm_script_single_vs_multi():
    from repro.launch import slurm
    s1 = slurm.render_script("j", "/img", "python", nodes=1)
    assert "mpiexec" not in s1 and "ch-run /img" in s1
    s2 = slurm.render_script("j", "/img", "python", nodes=16)
    assert "mpiexec -n 16 -ppn 1 ch-run /img" in s2
