"""Charliecloud-capsule workflow + site security policy tests."""
import json
import os
from pathlib import Path

import pytest

from repro.core import container as C
from repro.core import deploy as D
from repro.core.registry import OfflineViolation, default_index


@pytest.fixture
def pipeline():
    return D.DeploymentPipeline(index=default_index())


def test_build_requires_workstation():
    builder = C.ImageBuilder(default_index(), context=C.CLUSTER)
    with pytest.raises(OfflineViolation):
        builder.build(C.ImageDefinition("x", requirements=("numpy>=1.14",)))


def test_full_pipeline_and_run(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t1"), tmp_path, nodes=4)
    assert dep.archive.exists() and dep.unpacked.exists()
    assert "mpiexec -n 4 -ppn 1 ch-run" in dep.slurm_script
    res = dep.run(lambda: os.environ["REPRO_CAPSULE"], ranks=3)
    assert [r.value for r in res] == ["t1"] * 3
    assert res[1].rank == 1 and res[1].world_size == 3


def test_env_scrubbed_and_restored(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t2"), tmp_path)
    os.environ["SSH_AUTH_SOCK"] = "/tmp/ssh-evil"
    try:
        res = dep.run(lambda: os.environ.get("SSH_AUTH_SOCK", "SCRUBBED"))
        assert res[0].value == "SCRUBBED"
        assert os.environ["SSH_AUTH_SOCK"] == "/tmp/ssh-evil"  # restored
    finally:
        del os.environ["SSH_AUTH_SOCK"]


def test_pip_inside_capsule_dies(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t3"), tmp_path)
    with pytest.raises(OfflineViolation):
        dep.run(lambda: C.capsule_pip_install("pandas"))


def test_image_immutability(tmp_path, pipeline):
    dep = pipeline.deploy(D.intel_tensorflow_image("t4"), tmp_path)

    def vandalize():
        root = Path(os.environ["REPRO_CAPSULE_ROOT"])
        (root / "image" / "manifest.json").write_text("{}")
        return True

    with pytest.raises(C.SecurityError, match="immutability"):
        dep.run(vandalize)
    # with -w (writeable) it is allowed, like ch-run -w
    dep2 = pipeline.deploy(D.intel_tensorflow_image("t5"), tmp_path)
    dep2.runtime.run(dep2.unpacked, vandalize, writeable=True)


def test_unpack_refuses_hash_mismatch(tmp_path):
    idx = default_index()
    b = C.ImageBuilder(idx)
    img1 = b.build(C.ImageDefinition("same-name", requirements=("numpy>=1.14",)))
    img2 = b.build(C.ImageDefinition("same-name", requirements=("six>=1.10",)))
    a1 = C.flatten(img1, tmp_path / "w1")
    a2 = C.flatten(img2, tmp_path / "w2")
    C.unpack(a1, tmp_path / "tmpfs")
    with pytest.raises(C.SecurityError, match="hash mismatch"):
        C.unpack(a2, tmp_path / "tmpfs")


def test_unpack_refuses_partial_tree(tmp_path):
    """A crashed prior ch-tar2dir leaves a partial dest (no manifest, or
    a corrupt one) — that must read as the same hash-mismatch refusal,
    not leak a FileNotFoundError / JSONDecodeError."""
    idx = default_index()
    img = C.ImageBuilder(idx).build(
        C.ImageDefinition("partial", requirements=("numpy>=1.14",)))
    archive = C.flatten(img, tmp_path / "w")
    dest = tmp_path / "tmpfs" / "partial"
    dest.mkdir(parents=True)              # partial tree: no manifest at all
    with pytest.raises(C.SecurityError, match="hash mismatch"):
        C.unpack(archive, tmp_path / "tmpfs")
    (dest / "image").mkdir()
    (dest / "image/manifest.json").write_text("{truncated")   # corrupt
    with pytest.raises(C.SecurityError, match="hash mismatch"):
        C.unpack(archive, tmp_path / "tmpfs")
    (dest / "image/manifest.json").write_text("{}")           # hashless
    with pytest.raises(C.SecurityError, match="hash mismatch"):
        C.unpack(archive, tmp_path / "tmpfs")


def test_interleaved_capsule_env_frames(tmp_path, pipeline):
    """Two in-process capsules interleaved non-LIFO (A enters, B enters,
    A exits, B exits): B's frame must survive A's exit intact, scrubbed
    vars stay scrubbed while any frame is live, and the last exit
    restores the host environment exactly.  The old snapshot/restore
    scheme failed all three."""
    dep_a = pipeline.deploy(D.intel_tensorflow_image("cap-a"), tmp_path)
    dep_b = pipeline.deploy(D.intel_tensorflow_image("cap-b"), tmp_path)
    os.environ["SSH_AUTH_SOCK"] = "/tmp/ssh-interleave"
    try:
        baseline = dict(os.environ)
        rt = dep_a.runtime
        man_a = json.loads(
            (dep_a.unpacked / "image/manifest.json").read_text())
        man_b = json.loads(
            (dep_b.unpacked / "image/manifest.json").read_text())
        cm_a = rt._capsule_env(dep_a.unpacked, man_a, None)
        cm_b = rt._capsule_env(dep_b.unpacked, man_b, None)
        cm_a.__enter__()
        assert os.environ["REPRO_CAPSULE"] == "cap-a"
        cm_b.__enter__()
        assert os.environ["REPRO_CAPSULE"] == "cap-b"  # last entrant wins
        cm_a.__exit__(None, None, None)                # non-LIFO exit
        assert os.environ["REPRO_CAPSULE"] == "cap-b"
        assert os.environ["REPRO_CAPSULE_ROOT"] == str(dep_b.unpacked)
        assert "SSH_AUTH_SOCK" not in os.environ       # still scrubbed
        cm_b.__exit__(None, None, None)
        assert dict(os.environ) == baseline            # exact restore
    finally:
        os.environ.pop("SSH_AUTH_SOCK", None)


def test_fn_receives_composed_capsule_env(tmp_path, pipeline):
    """Functions declaring a ``capsule_env`` parameter get the composed
    per-run frame directly — the race-free alternative to reading
    os.environ while another capsule may be live."""
    dep = pipeline.deploy(D.intel_tensorflow_image("t6"), tmp_path)
    res = dep.runtime.run(
        dep.unpacked,
        lambda capsule_env: (capsule_env["REPRO_CAPSULE"],
                             capsule_env["REPRO_NO_NETWORK"]),
        env={"EXTRA": "1"})
    assert res.value == ("t6", "1")


def test_site_policy_rejects_docker_singularity_admits_charliecloud():
    pol = C.SecurityPolicy()
    with pytest.raises(C.SecurityError):
        pol.admit(C.RUNTIME_PROFILES["docker"])
    with pytest.raises(C.SecurityError):
        pol.admit(C.RUNTIME_PROFILES["singularity"])
    pol.admit(C.RUNTIME_PROFILES["charliecloud"])  # no raise


def test_slurm_script_single_vs_multi():
    from repro.launch import slurm
    s1 = slurm.render_script("j", "/img", "python", nodes=1)
    assert "mpiexec" not in s1 and "ch-run /img" in s1
    s2 = slurm.render_script("j", "/img", "python", nodes=16)
    assert "mpiexec -n 16 -ppn 1 ch-run /img" in s2


def test_slurm_omp_threads_clamp():
    from repro.launch import slurm
    s = slurm.render_script("j", "/img", "python", threads_per_rank=96)
    assert "export OMP_NUM_THREADS=48" in s
    # a 1-cpu rank must not render OMP_NUM_THREADS=0 (would disable
    # the OpenMP runtime entirely on real systems)
    s1 = slurm.render_script("j", "/img", "python", threads_per_rank=1)
    assert "export OMP_NUM_THREADS=1" in s1


def test_slurm_env_values_are_shell_quoted():
    from repro.launch import slurm
    s = slurm.render_script(
        "j", "/img", "python",
        env={"SPOOL": "/tmp/my spool/dir",
             "SPEC": '{"config": "qwen2-0.5b"}'})
    assert "export SPOOL='/tmp/my spool/dir'" in s
    assert """export SPEC='{"config": "qwen2-0.5b"}'""" in s
