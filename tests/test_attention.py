"""Attention unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import attention as A


def _naive(q, k, v, scale, causal, window=None, softcap=None):
    """Unchunked reference in f64."""
    B, Sq, KV, G, D = q.shape
    Skv = k.shape[1]
    qf = np.asarray(q, np.float64)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    s = np.einsum("bqkgd,btkd->bkgqt", qf, kf) * scale
    if softcap:
        s = softcap * np.tanh(s / softcap)
    qpos = np.arange(Sq)[:, None]
    kpos = np.arange(Skv)[None, :]
    m = np.ones((Sq, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= (qpos - kpos) < window
    s = np.where(m, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bkgqt,btkd->bqkgd", p, vf)


@pytest.mark.parametrize("window,softcap,causal", [
    (None, None, True), (8, None, True), (None, 30.0, True),
    (4, 50.0, True), (None, None, False)])
def test_attend_matches_naive(window, softcap, causal, rng_key):
    B, S, KV, G, D = 2, 64, 2, 3, 16
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    out = A.attend(q, k, v, scale=0.25, causal=causal, window=window,
                   softcap_val=softcap, q_chunk=16)
    ref = _naive(q, k, v, 0.25, causal, window, softcap)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_chunked_equals_unchunked(rng_key):
    B, S, KV, G, D = 1, 128, 1, 2, 8
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    o1 = A.attend(q, k, v, scale=0.3, causal=True, q_chunk=0)
    o2 = A.attend(q, k, v, scale=0.3, causal=True, q_chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_rope_preserves_norm_and_relativity(rng_key):
    """RoPE is a rotation (norm preserved) and q.k depends only on the
    position DIFFERENCE."""
    B, S, H, D = 1, 8, 1, 32
    q = jax.random.normal(rng_key, (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qr = A.apply_rope(q, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(qr), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1), rtol=1e-5)
    # relativity: shift all positions by 17, pairwise dots unchanged
    qr2 = A.apply_rope(q, pos + 17)
    d1 = np.einsum("bshd,bthd->bst", np.asarray(qr), np.asarray(qr))
    d2 = np.einsum("bshd,bthd->bst", np.asarray(qr2), np.asarray(qr2))
    np.testing.assert_allclose(d1, d2, atol=1e-4)


def test_mrope_sections_select_position_streams(rng_key):
    """With all three streams equal, M-RoPE == standard RoPE."""
    B, S, H, D = 1, 6, 1, 16
    q = jax.random.normal(rng_key, (B, S, H, D))
    pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    r1 = A.apply_rope(q, pos1)
    r3 = A.apply_rope(q, pos3, mrope_sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r3), atol=1e-6)


def test_decode_cache_matches_prefill(rng_key):
    """attention_block decode over a growing cache == full-sequence block."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=11,
                      dtype="float32")
    params = A.init_attention(rng_key, cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (B, S, 32))
    full, _ = A.attention_block(params, cfg, x, causal=True)
    cache = {"k": jnp.zeros((B, S, 2, 8)), "v": jnp.zeros((B, S, 2, 8))}
    outs = []
    for t in range(S):
        o, cache = A.attention_block(
            params, cfg, x[:, t:t + 1],
            positions=jnp.full((B, 1), t),
            cache=cache, cache_index=jnp.full((B,), t, jnp.int32))
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(sq=st.integers(1, 24), skv=st.integers(1, 48),
       window=st.one_of(st.none(), st.integers(1, 16)))
def test_mask_bias_properties(sq, skv, window):
    """Causal mask: row i admits exactly min(i+1, window) keys (within skv)."""
    bias = A._mask_bias(jnp.arange(sq), jnp.arange(skv), causal=True,
                        window=window)
    admitted = np.asarray(bias == 0.0).sum(axis=-1)
    for i in range(sq):
        lo = 0 if window is None else max(0, i - window + 1)
        hi = min(i, skv - 1)                  # causal upper bound
        expect = max(0, hi - lo + 1)
        assert admitted[i] == expect, (i, admitted[i], expect)
