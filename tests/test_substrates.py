"""Optimizers, schedules, checkpointing, data pipelines, serving."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro import checkpoint as ck
from repro import optim
from repro.data import (CalorimeterSpec, CalorimeterSource,
                        SyntheticTokenSource, TokenDatasetSpec, generate_batch)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic(params):
    return sum(jnp.sum(jnp.square(p)) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("name,kw", [
    ("sgd", {}), ("sgd", {"momentum": 0.9}), ("rmsprop", {}),
    ("adam", {}), ("adamw", {"weight_decay": 0.01})])
def test_optimizers_minimize_quadratic(name, kw):
    opt = optim.get(name, 0.05, **kw)
    params = {"a": jnp.ones((4,)), "b": jnp.full((2, 3), -2.0)}
    state = opt.init(params)
    v0 = float(_quadratic(params))
    for _ in range(100):
        g = jax.grad(_quadratic)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(_quadratic(params)) < 0.05 * v0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-4
    assert abs(float(norm) - np.sqrt(1000.0)) < 1e-2
    small = {"a": jnp.full((4,), 0.01)}
    c2, _ = optim.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01, rtol=1e-5)


def test_warmup_cosine_schedule():
    s = optim.schedules.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(s(jnp.asarray(100))) < 1e-3
    assert float(s(jnp.asarray(55))) < float(s(jnp.asarray(20)))


def test_bf16_grads_accumulate_in_f32():
    opt = optim.adamw(1e-2)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    upd, state = opt.update(g, state, params)
    assert state["mu"]["w"].dtype == jnp.float32
    assert upd["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_rotation(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "count": jnp.asarray(7)}
    for step in (1, 2, 3, 4, 5):
        ck.save(tmp_path, step, tree, keep=3)
    assert ck.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert len(kept) == 3 and kept[0] == "step_000000003"
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    back = ck.restore(tmp_path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_missing_leaf_raises(tmp_path):
    ck.save(tmp_path, 1, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        ck.restore(tmp_path, {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                              "extra": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_checkpoint_manifest(tmp_path):
    ck.save(tmp_path, 3, {"w": jnp.zeros((2, 2))}, extra={"loss": 1.5})
    m = ck.manifest(tmp_path)
    assert m["step"] == 3 and m["extra"]["loss"] == 1.5
    assert m["leaves"]["w"]["shape"] == [2, 2]


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------

def test_token_source_determinism_and_sharding():
    spec = TokenDatasetSpec(vocab_size=97, seq_len=32, global_batch=8)
    s0 = SyntheticTokenSource(spec, rank=0, world_size=2)
    s1 = SyntheticTokenSource(spec, rank=1, world_size=2)
    b0a, b0b = s0.batch(5), s0.batch(5)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    assert s0.local_batch == 4
    assert not np.array_equal(s0.batch(5)["tokens"], s1.batch(5)["tokens"])
    assert b0a["tokens"].max() < 97 and b0a["tokens"].min() >= 0


def test_token_source_learnable_structure():
    """next-token follows the permutation table > noise of the time."""
    spec = TokenDatasetSpec(vocab_size=50, seq_len=256, global_batch=4,
                            noise=0.2)
    s = SyntheticTokenSource(spec)
    b = s.batch(0)["tokens"]
    follows = (s._table[b[:, :-1]] == b[:, 1:]).mean()
    assert follows > 0.6


@settings(max_examples=10, deadline=None)
@given(batch=st.sampled_from([2, 4, 8]), step=st.integers(0, 100))
def test_calorimeter_physics(batch, step):
    b = generate_batch(CalorimeterSpec(), batch, step)
    img, e = b["images"], b["energies"]
    assert img.shape == (batch, 25, 25, 25, 1)
    assert (img >= 0).all()
    totals = img.sum((1, 2, 3, 4))
    # total deposition grows with primary energy
    if batch >= 4:
        corr = np.corrcoef(e, totals)[0, 1]
        assert corr > 0.8
    # lateral profile peaks at the center
    core = img[:, 12, 12, :, 0].sum(-1)
    edge = img[:, 0, 0, :, 0].sum(-1)
    assert (core > edge).all()


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serving_engine_greedy_deterministic(rng_key):
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import Request, SamplingParams, ServingEngine
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, rng_key)
    eng = ServingEngine(cfg, params, max_seq_len=48, max_slots=2)
    prompt = np.array([1, 2, 3, 4], np.int32)
    sp = SamplingParams(max_new_tokens=6, greedy=True)
    o1 = eng.generate([Request(prompt, sp)])[0]
    o2 = eng.generate([Request(prompt, sp)])[0]
    np.testing.assert_array_equal(o1, o2)
    assert len(o1) == 6 and (o1 < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# sharded dataset (the paper's HDF5-on-GPFS analogue)
# ---------------------------------------------------------------------------

def test_sharded_dataset_roundtrip_and_rank_split(tmp_path):
    from repro.data.shards import ShardedDataset, write_dataset

    def gen():
        for step in range(10):
            b = generate_batch(CalorimeterSpec(), 64, step)
            yield b

    path = write_dataset(tmp_path / "calo", gen(), events_per_shard=128)
    ds0 = ShardedDataset(path, rank=0, world_size=2)
    ds1 = ShardedDataset(path, rank=1, world_size=2)
    assert ds0.verify() and ds1.verify()
    assert ds0.local_events + ds1.local_events == 640
    files0 = {s["file"] for s in ds0.my_shards}
    files1 = {s["file"] for s in ds1.my_shards}
    assert not files0 & files1                      # disjoint rank subsets

    batches = list(ds0.epoch(0, batch_size=50))
    assert all(b["images"].shape == (50, 25, 25, 25, 1) for b in batches)
    assert sum(len(b["energies"]) for b in batches) <= ds0.local_events
    # deterministic per (seed, epoch, rank)
    b2 = list(ds0.epoch(0, batch_size=50))
    np.testing.assert_array_equal(batches[0]["energies"], b2[0]["energies"])
    # different epoch shuffles differently
    b3 = list(ds0.epoch(1, batch_size=50))
    assert not np.array_equal(batches[0]["energies"], b3[0]["energies"])


def test_sharded_dataset_detects_corruption(tmp_path):
    from repro.data.shards import ShardedDataset, write_dataset

    def gen():
        yield {"x": np.arange(32, dtype=np.float32)}

    path = write_dataset(tmp_path / "d", gen(), events_per_shard=16)
    ds = ShardedDataset(path)
    shard_file = path / ds.my_shards[0]["file"]
    shard_file.write_bytes(shard_file.read_bytes()[:-1] + b"X")
    with pytest.raises(IOError, match="corrupt"):
        ds.verify()


def test_serving_engine_encdec_whisper(rng_key):
    """enc-dec (whisper) serving: encoder runs once, decoder streams."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import Request, SamplingParams, ServingEngine
    cfg = get_smoke_config("whisper-small")
    params = T.init_params(cfg, rng_key)
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=2)
    rng = np.random.default_rng(0)
    reqs = [Request(np.array([1], np.int32),
                    SamplingParams(max_new_tokens=5, greedy=True),
                    encoder_input=rng.normal(
                        size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32))
            for _ in range(2)]
    outs = eng.generate(reqs)
    assert all(len(o) == 5 and (o < cfg.vocab_size).all() for o in outs)
    # different audio -> different transcription (encoder matters)
    reqs2 = [Request(np.array([1], np.int32),
                     SamplingParams(max_new_tokens=5, greedy=True),
                     encoder_input=rng.normal(
                         size=(cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 3)
             for _ in range(2)]
    outs2 = eng.generate(reqs2)
    assert not all(np.array_equal(a, b) for a, b in zip(outs, outs2))
