"""3DGAN (the paper's workload) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.data import CalorimeterSpec, generate_batch
from repro.models import gan3d as G


@pytest.fixture(scope="module")
def cfg():
    return G.GAN3DConfig(g_fc_ch=6, g_base=16, d_base=8)   # fast variant


def test_parameter_budget():
    full = G.GAN3DConfig()
    gp = G.init_generator(jax.random.PRNGKey(0), full)
    dp = G.init_discriminator(jax.random.PRNGKey(1), full)
    total = G.param_count(gp) + G.param_count(dp)
    assert 0.8e6 < total < 1.3e6      # paper: "slightly less than 1 million"


def test_generator_output_properties(cfg, rng_key):
    gp = G.init_generator(rng_key, cfg)
    z = jax.random.normal(rng_key, (4, cfg.latent_dim))
    e = jnp.asarray([50.0, 150.0, 300.0, 450.0])
    img = G.generator(gp, cfg, z, e)
    assert img.shape == (4, 25, 25, 25, 1)
    assert float(img.min()) >= 0.0                   # energies non-negative
    totals = np.asarray(jnp.sum(img, axis=(1, 2, 3, 4)))
    assert totals[3] > totals[0]                     # conditioning monotone-ish


def test_discriminator_heads(cfg, rng_key):
    dp = G.init_discriminator(rng_key, cfg)
    batch = generate_batch(CalorimeterSpec(), 4)
    out = G.discriminator(dp, cfg, jnp.asarray(batch["images"]))
    assert out["adv_logit"].shape == (4,)
    assert (np.asarray(out["energy_pred"]) >= 0).all()


def test_losses_finite_and_grads_flow(cfg, rng_key):
    gp = G.init_generator(rng_key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(rng_key, 1), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in generate_batch(CalorimeterSpec(), 4).items()}
    z = jax.random.normal(rng_key, (4, cfg.latent_dim))
    gd, m = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch, z)
    assert np.isfinite(float(m["d_loss"]))
    assert float(optim.global_norm(gd)) > 0
    gg, mg = jax.grad(G.g_loss, has_aux=True)(gp, dp, cfg, batch, z)
    assert np.isfinite(float(mg["g_loss"]))
    assert float(optim.global_norm(gg)) > 0


def test_d_stop_gradient_isolates_generator(cfg, rng_key):
    """d_loss must NOT backprop into the generator."""
    gp = G.init_generator(rng_key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(rng_key, 1), cfg)
    batch = {k: jnp.asarray(v)
             for k, v in generate_batch(CalorimeterSpec(), 2).items()}
    z = jax.random.normal(rng_key, (2, cfg.latent_dim))
    g_wrt_g = jax.grad(lambda g_: G.d_loss(dp, g_, cfg, batch, z)[0])(gp)
    assert float(optim.global_norm(g_wrt_g)) == 0.0


def test_short_training_moves_losses(cfg, rng_key):
    gp = G.init_generator(rng_key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(rng_key, 1), cfg)
    d_opt = optim.rmsprop(1e-3)
    g_opt = optim.rmsprop(1e-3)
    ds, gs = d_opt.init(dp), g_opt.init(gp)

    @jax.jit
    def step(dp, ds, gp, gs, batch, z):
        gd, dm = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch, z)
        du, ds = d_opt.update(gd, ds, dp)
        dp = optim.apply_updates(dp, du)
        gg, gm = jax.grad(G.g_loss, has_aux=True)(gp, dp, cfg, batch, z)
        gu, gs = g_opt.update(gg, gs, gp)
        gp = optim.apply_updates(gp, gu)
        return dp, ds, gp, gs, dm, gm

    key = rng_key
    d0 = None
    for i in range(6):
        batch = {k: jnp.asarray(v)
                 for k, v in generate_batch(CalorimeterSpec(), 4, i).items()}
        key, kz = jax.random.split(key)
        z = jax.random.normal(kz, (4, cfg.latent_dim))
        dp, ds, gp, gs, dm, gm = step(dp, ds, gp, gs, batch, z)
        if d0 is None:
            d0 = float(dm["d_loss"])
    assert float(dm["d_loss"]) < d0        # D learns real vs fake quickly
