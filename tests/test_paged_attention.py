"""Paged attention: Pallas decode kernel over block tables + the paged
serving path (block storage, undersized pools, preemption) — validated
in interpret mode on CPU with the dense engine as the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import attention_ref, paged_attention_ref
from repro.serving import (OutOfBlocks, PagedKVCache, Request,
                           SamplingParams, Scheduler, ServingEngine)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pages(key, B, KV, G, D, NP, page, pps, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, KV, G, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KV, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KV, D), dtype)
    tbl = jax.random.randint(ks[3], (B, pps), 0, NP, jnp.int32)
    return q, kp, vp, tbl


# ---------------------------------------------------------------------------
# kernel vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,KV,G,D,NP,page,pps,window,softcap", [
    (3, 2, 2, 32, 9, 8, 4, None, None),
    (2, 1, 4, 16, 5, 4, 4, 6, None),
    (4, 2, 1, 64, 17, 16, 3, None, 30.0),
    (1, 1, 1, 8, 2, 4, 2, 3, 10.0),
])
def test_paged_kernel_matches_ref(B, KV, G, D, NP, page, pps, window,
                                  softcap, rng_key):
    q, kp, vp, tbl = _pages(rng_key, B, KV, G, D, NP, page, pps)
    lens = jnp.array([1 + (7 * i) % (pps * page) for i in range(B)],
                     jnp.int32)
    out = paged_attention(q, kp, vp, tbl, lens, window=window,
                          softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tbl, lens, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_paged_ref_matches_dense_attention(rng_key):
    """Gathering pages laid out by a permutation table reproduces dense
    contiguous attention exactly: paging changes layout, not math."""
    B, KV, G, D, page, pps = 2, 2, 2, 16, 4, 4
    T = page * pps
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, KV, G, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    # scatter the dense sequences into pages via a permuted table
    perm = np.random.default_rng(0).permutation(B * pps)
    tbl = jnp.asarray(perm.reshape(B, pps), jnp.int32)
    kp = jnp.zeros((B * pps, page, KV, D))
    vp = jnp.zeros((B * pps, page, KV, D))
    for b in range(B):
        for j in range(pps):
            kp = kp.at[perm[b * pps + j]].set(
                k[b, j * page:(j + 1) * page])
            vp = vp.at[perm[b * pps + j]].set(
                v[b, j * page:(j + 1) * page])
    lens = jnp.array([T, T - 3], jnp.int32)
    out = paged_attention_ref(q, kp, vp, tbl, lens)
    # dense oracle: fold (B, KV, G) and attend with the last-row slice
    for b in range(B):
        L = int(lens[b])
        qf = q[b].reshape(KV * G, 1, D)
        kf = jnp.repeat(k[b, :L].transpose(1, 0, 2), G, axis=0)
        vf = jnp.repeat(v[b, :L].transpose(1, 0, 2), G, axis=0)
        # causal with a single query at the LAST position == no mask
        ref = attention_ref(qf, kf, vf, causal=False)
        np.testing.assert_allclose(
            np.asarray(out[b].reshape(KV * G, 1, D)), np.asarray(ref),
            atol=2e-5, rtol=2e-5)


def test_ops_wrapper_gqa_layout(rng_key):
    """Model layout (B, 1, H, D) folds to grouped heads consistently."""
    B, KV, G, D, NP, page, pps = 2, 2, 3, 16, 7, 4, 3
    q, kp, vp, tbl = _pages(rng_key, B, KV, G, D, NP, page, pps)
    lens = jnp.array([5, 11], jnp.int32)
    ref = paged_attention_ref(q, kp, vp, tbl, lens)
    qm = q.reshape(B, 1, KV * G, D)
    out = ops.paged_decode_attention(qm, kp, vp, tbl, lens, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out[:, 0].reshape(B, KV, G, D)), np.asarray(ref),
        atol=2e-6, rtol=2e-6)


def test_kernel_ignores_garbage_table_entries(rng_key):
    """Entries past a sequence's length (trash/stale ids, even
    out-of-range) must not change the result."""
    B, KV, G, D, NP, page, pps = 1, 1, 2, 16, 6, 4, 4
    q, kp, vp, tbl = _pages(rng_key, B, KV, G, D, NP, page, pps)
    lens = jnp.array([6], jnp.int32)                   # pages 2, 3 unused
    base = paged_attention(q, kp, vp, tbl, lens, interpret=True)
    junk = tbl.at[0, 2].set(99999).at[0, 3].set(-7)
    out = paged_attention(q, kp, vp, junk, lens, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


# ---------------------------------------------------------------------------
# paged cache storage
# ---------------------------------------------------------------------------

def test_paged_cache_storage_and_tables(qwen):
    cfg, _ = qwen
    kv = PagedKVCache(cfg, max_slots=2, max_seq_len=32, block_size=8,
                      paged=True, num_blocks=5)
    # storage: batch axis -> blocks (+1 trash), seq axis -> one block
    leaf = jax.tree.leaves(kv.cache)[0]
    assert leaf.shape[-4] == 6 and leaf.shape[-3] == 8
    s = kv.alloc_slot(prompt_len=10)                   # 2 blocks
    tbl = np.asarray(kv.device_block_tables())
    assert list(tbl[s, :2]) == kv.block_table[s]
    assert all(tbl[s, 2:] == kv.trash_block)
    kv.ensure_capacity(s, 17)                          # third block
    tbl = np.asarray(kv.device_block_tables())
    assert list(tbl[s, :3]) == kv.block_table[s]
    kv.free_slot(s)
    assert (np.asarray(kv.device_block_tables()) == kv.trash_block).all()
    assert kv.pool.in_use == 0


def test_paged_pool_smaller_than_worst_case_is_real(qwen):
    cfg, _ = qwen
    kv = PagedKVCache(cfg, max_slots=4, max_seq_len=32, block_size=8,
                      paged=True, num_blocks=3)
    s0 = kv.alloc_slot(prompt_len=16)                  # 2 blocks
    with pytest.raises(OutOfBlocks):
        kv.alloc_slot(prompt_len=16)                   # needs 2, 1 left
    # failed alloc is all-or-nothing: nothing leaked
    assert kv.pool.in_use == 2 and kv.free_slot_count == 3
    s1 = kv.alloc_slot(prompt_len=5)                   # 1 block fits
    with pytest.raises(OutOfBlocks):
        kv.ensure_capacity(s1, 9)                      # pool dry
    kv.free_slot(s0)
    kv.ensure_capacity(s1, 9)                          # recycled
    assert kv.pool.in_use == 2


def test_dense_mode_rejects_num_blocks_knob(qwen):
    cfg, _ = qwen
    with pytest.raises(ValueError):
        PagedKVCache(cfg, max_slots=2, max_seq_len=32, block_size=8,
                     num_blocks=3)


def test_paged_rejects_nonpositional_families():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("mamba2-1.3b")
    with pytest.raises(ValueError):
        PagedKVCache(cfg, max_slots=2, max_seq_len=32, block_size=8,
                     paged=True)


# ---------------------------------------------------------------------------
# end-to-end: paged engine == dense engine
# ---------------------------------------------------------------------------

def _outputs(qwen, prompts, sps, **kw):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=64, max_slots=3,
                        kv_block_size=16, **kw)
    sched = Scheduler(eng)
    rids = [sched.submit(Request(p, sp)) for p, sp in zip(prompts, sps)]
    sched.run()
    return [sched.output(r) for r in rids], eng, sched


def test_paged_engine_bit_identical_to_dense(qwen):
    cfg, _ = qwen
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (5, 12, 3, 20, 7)]
    sps = [SamplingParams(max_new_tokens=m, greedy=True)
           for m in (6, 4, 8, 5, 7)]
    dense, _, _ = _outputs(qwen, prompts, sps, paged=False)
    paged, eng, _ = _outputs(qwen, prompts, sps, paged=True)
    for a, b in zip(dense, paged):
        np.testing.assert_array_equal(a, b)
    assert eng.kv.paged and eng.kv.pool.in_use == 0


def test_undersized_pool_stress_no_drops_no_leaks(qwen):
    """num_blocks far below worst case + mixed prompt lengths + prefix
    cache on: every request completes (none dropped), greedy outputs
    match the dense path bit-for-bit, and at drain every prefix pin has
    been released (the whole tree is evictable)."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, n,
                                            dtype=np.int32)])
               for n in (7, 15, 4, 11, 9, 6, 2, 13)]
    sps = [SamplingParams(max_new_tokens=10, greedy=True) for _ in prompts]

    def serve(**kw):
        eng = ServingEngine(cfg, params, max_seq_len=48, max_slots=4,
                            kv_block_size=8, **kw)
        sched = Scheduler(eng)
        rids = [sched.submit(Request(p, sp))
                for p, sp in zip(prompts, sps)]
        sched.run()
        return [sched.output(r) for r in rids], eng, sched

    dense, _, _ = serve(paged=False)
    # worst case would be 4 slots * 6 blocks = 24; give it 7
    paged, eng, sched = serve(paged=True, num_blocks=7,
                              prefix_cache_blocks=8)
    assert len(paged) == len(prompts)                  # nobody dropped
    for a, b in zip(dense, paged):
        np.testing.assert_array_equal(a, b)
    # the pool actually ran dry and the scheduler coped
    assert sched.preemptions + sched.admission_stalls > 0
    assert eng.kv.pool.high_water == 7
    # drain state: no KV blocks held, no leaked prefix pins — with every
    # request retired the full radix tree must be evictable
    assert eng.kv.pool.in_use == 0
    eng.prefix_cache.evict(10 ** 9)
    assert eng.kv.prefix_pool.in_use == 0


def test_preempted_request_resumes_correctly(qwen):
    """Force a decode-time preemption and check the deferred request's
    final output still matches its solo greedy run."""
    cfg, params = qwen
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (14, 14)]
    sps = [SamplingParams(max_new_tokens=12, greedy=True)] * 2
    solo = [_outputs(qwen, [p], [sp], paged=False)[0][0]
            for p, sp in zip(prompts, sps)]

    cfgp = dict(paged=True, num_blocks=4, kv_block_size=8)
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=2, **cfgp)
    sched = Scheduler(eng)
    rids = [sched.submit(Request(p, sp)) for p, sp in zip(prompts, sps)]
    sched.run()
    assert sched.preemptions > 0                       # really preempted
    for rid, ref in zip(rids, solo):
        np.testing.assert_array_equal(sched.output(rid), ref)
