"""MoE dispatch tests: capacity bounds, combine correctness, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models import moe as M


def _cfg(E=4, K=2, cf=2.0, d=32, dff=64):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=d,
                       num_heads=4, num_kv_heads=2, d_ff=dff, vocab_size=11,
                       num_experts=E, num_experts_per_tok=K,
                       moe_capacity_factor=cf, dtype="float32")


def test_dispatch_indices_capacity_and_ranks(rng_key):
    T_, K, E, C = 64, 2, 4, 16
    eidx = jax.random.randint(rng_key, (T_, K), 0, E)
    e, r, keep = M._dispatch_indices(eidx, C)
    e, r, keep = np.asarray(e), np.asarray(r), np.asarray(keep)
    assert (r[keep] < C).all()
    # kept (expert, rank) pairs are unique — no slot collisions
    pairs = set(zip(e[keep].tolist(), r[keep].tolist()))
    assert len(pairs) == keep.sum()
    # ranks are dense per expert: 0..count-1
    for ex in range(E):
        rs = sorted(r[keep & (e == ex)].tolist())
        assert rs == list(range(len(rs)))


def test_moe_block_with_large_capacity_equals_dense_mixture(rng_key):
    """With capacity big enough to keep every token, the block must equal the
    explicit per-token weighted mixture of its experts."""
    cfg = _cfg(E=4, K=2, cf=8.0)
    params = M.init_moe(rng_key, cfg)
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (2, 8, cfg.d_model))
    out, aux = M.moe_block(params, cfg, x)

    # explicit reference
    import repro.models.modules as nn
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ np.asarray(params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    a = jax.nn.silu
    ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(2):
            e = int(eidx[t, j])
            h = (a(xf[t] @ params["wg"][e]) * (xf[t] @ params["wi"][e])) \
                @ params["wo"][e]
            ref[t] += float(gate[t, j]) * np.asarray(h)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref,
                               atol=2e-3)


def test_capacity_drops_lower_ranked_tokens(rng_key):
    """With capacity 8 and all tokens forced to one expert, later tokens are
    dropped (zero output)."""
    cfg = _cfg(E=4, K=1, cf=1.0)
    params = M.init_moe(rng_key, cfg)
    # rig the router so expert 0 always wins: logits = w.x with positive x
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"]) \
        .at[:, 1:].set(-100.0)
    x = jnp.abs(jax.random.normal(jax.random.fold_in(rng_key, 2),
                                  (1, 64, cfg.d_model))) + 0.1
    out, aux = M.moe_block(params, cfg, x)
    C = M.expert_capacity(64, cfg)
    o = np.abs(np.asarray(out))[0]
    assert (o[:C].sum(axis=-1) > 0).all()        # first C kept
    np.testing.assert_allclose(o[C:], 0.0)       # the rest dropped
    assert float(aux) > 0.0                      # imbalance penalized


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), E=st.sampled_from([2, 4]),
       K=st.sampled_from([1, 2]))
def test_moe_output_finite_and_shaped(seed, E, K):
    key = jax.random.PRNGKey(seed)
    cfg = _cfg(E=E, K=K)
    params = M.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    out, aux = M.moe_block(params, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all() and np.isfinite(float(aux))


def test_balanced_router_minimizes_aux(rng_key):
    cfg = _cfg(E=4, K=1)
    params = M.init_moe(rng_key, cfg)
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(rng_key, (2, 32, cfg.d_model))
    _, aux_uniform = M.moe_block(params, cfg, x)
    params["router"]["w"] = params["router"]["w"].at[:, 1:].set(-100.0)
    _, aux_skewed = M.moe_block(params, cfg, x)
    assert float(aux_skewed) > float(aux_uniform)
