"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned archs: instantiate the REDUCED same-family
variant (<=2-ish layers, d_model<=512, <=4 experts), run one forward and
one train step on CPU, assert output shapes and no NaNs; plus one decode
step against a small cache.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import InputShape, synthesize_inputs
from repro.models import transformer as T

SMOKE_SHAPE = InputShape("smoke-train", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_shapes(arch, rng_key):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 5
    assert cfg.num_experts <= 4
    batch = synthesize_inputs(cfg, SMOKE_SHAPE, rng_key)
    params = T.init_params(cfg, rng_key)
    logits, aux = jax.jit(lambda p, b: T.forward(p, cfg, b))(params, batch)
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    batch = synthesize_inputs(cfg, SMOKE_SHAPE, rng_key)
    params = T.init_params(cfg, rng_key)
    opt = optim.adamw(1e-3, clip_norm=1.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, m), g = jax.value_and_grad(
            lambda p_: T.lm_loss(p_, cfg, b), has_aux=True)(p)
        upd, s = opt.update(g, s, p)
        return optim.apply_updates(p, upd), s, loss

    p1, s1, l1 = step(params, state, batch)
    p2, s2, l2 = step(p1, s1, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert float(l2) < float(l1)          # one step on same batch improves
    # params actually moved
    moved = any(not np.allclose(np.asarray(a, np.float32),
                                np.asarray(b, np.float32))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, rng_key):
    cfg = get_smoke_config(arch)
    B, Smax = 2, 64
    cache = T.init_cache(cfg, B, Smax)
    params = T.init_params(cfg, rng_key)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
             "positions": jnp.zeros((B,), jnp.int32), "cache": cache}
    if cfg.family == "encdec":
        batch["encoder_output"] = jnp.zeros(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.mrope:
        batch["mrope_positions"] = jnp.zeros((3, B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, b: T.decode_step(p, cfg, b))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


# ---------------------------------------------------------------------------
# Full-config sanity (no allocation: analytic checks only)
# ---------------------------------------------------------------------------

EXPECTED_PARAMS_B = {
    "whisper-small": (0.2, 0.45), "gemma2-27b": (26, 29),
    "dbrx-132b": (125, 140), "qwen3-moe-30b-a3b": (28, 33),
    "zamba2-1.2b": (0.9, 1.5), "qwen2-vl-72b": (68, 77),
    "gemma2-2b": (2.2, 3.2), "qwen2-0.5b": (0.4, 0.65),
    "mamba2-1.3b": (1.1, 1.5), "deepseek-coder-33b": (31, 36),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"
    # structural fields from the assignment table
    table = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    }
    L, d, h, kv, dff, v = table[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, dff, v)


def test_moe_experts_assignment():
    assert get_config("dbrx-132b").num_experts == 16
    assert get_config("dbrx-132b").num_experts_per_tok == 4
    assert get_config("qwen3-moe-30b-a3b").num_experts == 128
    assert get_config("qwen3-moe-30b-a3b").num_experts_per_tok == 8


def test_ssm_state_assignment():
    assert get_config("mamba2-1.3b").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
