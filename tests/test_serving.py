"""Continuous-batching serving subsystem: scheduler, paged KV cache,
replica gateway, telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (KVBlockPool, OutOfBlocks, PagedKVCache,
                           ReplicaGateway, Request, SamplingParams, Scheduler,
                           ServingEngine, launch_capsule_replicas)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, slots=2, seq=48, seed=0):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         rng_seed=seed)


# ---------------------------------------------------------------------------
# KV block pool / paged cache
# ---------------------------------------------------------------------------

def test_block_pool_never_double_allocates():
    pool = KVBlockPool(num_blocks=4, block_size=8)
    a, b = pool.alloc(), pool.alloc()
    assert a != b and pool.in_use == 2
    pool.free([a])
    seen = {b}
    for _ in range(3):                      # drain the pool completely
        blk = pool.alloc()
        assert blk not in seen, "block handed out while still in use"
        seen.add(blk)
    with pytest.raises(OutOfBlocks):
        pool.alloc()
    pool.free([b])
    with pytest.raises(AssertionError):     # double free is a hard error
        pool.free([b])


def test_block_pool_ring_recycling():
    pool = KVBlockPool(num_blocks=3, block_size=8)
    blocks = [pool.alloc() for _ in range(3)]
    pool.free(blocks)                       # freed in order -> ring tail
    assert [pool.alloc() for _ in range(3)] == blocks
    assert pool.high_water == 3


def test_paged_cache_slot_lifecycle(qwen):
    cfg, _ = qwen
    kv = PagedKVCache(cfg, max_slots=2, max_seq_len=32, block_size=8)
    s0 = kv.alloc_slot(prompt_len=10)       # 2 blocks
    s1 = kv.alloc_slot(prompt_len=3)        # 1 block
    assert s0 != s1 and kv.pool.in_use == 3
    kv.ensure_capacity(s1, 9)               # crosses into a second block
    assert len(kv.block_table[s1]) == 2
    with pytest.raises(OutOfBlocks):
        kv.alloc_slot(5)                    # no slot free
    kv.free_slot(s0)
    assert kv.pool.in_use == 2 and kv.free_slot_count == 1
    s2 = kv.alloc_slot(1)
    assert s2 == s0                         # slot recycled
    with pytest.raises(OutOfBlocks):
        kv.ensure_capacity(s2, 33)          # beyond max_seq_len
    occ = kv.occupancy()
    assert occ["slots_in_use"] == 2 and occ["block_high_water"] >= 3


# ---------------------------------------------------------------------------
# engine primitives / compatibility wrapper
# ---------------------------------------------------------------------------

def test_scheduler_matches_prerefactor_greedy_algorithm(qwen):
    """The scheduler path reproduces the seed engine's exact greedy loop
    (prefill last-logit sample, then one step per token) bit-for-bit."""
    from repro.models import transformer as T
    cfg, params = qwen
    # raw-argmax engine: the pre-refactor loop below has no tie break
    eng = ServingEngine(cfg, params, max_seq_len=48, max_slots=2,
                        rng_seed=0, greedy_tie_eps=0.0)
    prompt = np.array([5, 9, 2, 7], np.int32)
    out = eng.generate([Request(prompt, SamplingParams(max_new_tokens=6,
                                                       greedy=True))])[0]

    cache = T.init_cache(cfg, 1, 48)
    cache, pos, last = eng._prefill(params, jnp.asarray(prompt)[None],
                                    cache, None)
    tok = jnp.argmax(last, -1)
    ref = [int(tok[0])]
    for _ in range(5):
        logits, cache = eng._step(params, {"tokens": tok[:, None],
                                           "positions": pos, "cache": cache})
        pos = pos + 1
        tok = jnp.argmax(logits[:, 0], -1)
        ref.append(int(tok[0]))
    np.testing.assert_array_equal(out, np.asarray(ref, np.int32))


def test_continuous_batching_bit_identical_to_solo(qwen):
    """Greedy outputs of co-scheduled requests match serving each alone."""
    prompts = [np.array([1, 2, 3, 4], np.int32),
               np.array([9, 8, 7], np.int32),
               np.array([4, 4, 4, 4, 4, 4], np.int32)]
    sps = [SamplingParams(max_new_tokens=n, greedy=True) for n in (5, 8, 3)]
    solo = [_engine(qwen).generate([Request(p, sp)])[0]
            for p, sp in zip(prompts, sps)]
    batched = _engine(qwen).generate(
        [Request(p, sp) for p, sp in zip(prompts, sps)])
    for s, b in zip(solo, batched):
        np.testing.assert_array_equal(s, b)


def test_per_request_sampling_params(qwen):
    """Regression: seed engine applied requests[0].params to every row.
    A greedy request must stay greedy when batched after a stochastic one."""
    g_prompt = np.array([3, 1, 4, 1], np.int32)
    g_sp = SamplingParams(max_new_tokens=6, greedy=True)
    reference = _engine(qwen).generate([Request(g_prompt, g_sp)])[0]
    # stochastic request submitted FIRST: its params must not leak to row 1
    outs = _engine(qwen).generate([
        Request(np.array([7, 7, 7], np.int32),
                SamplingParams(max_new_tokens=6, temperature=5.0)),
        Request(g_prompt, g_sp)])
    np.testing.assert_array_equal(outs[1], reference)


def test_generate_accepts_more_requests_than_slots(qwen):
    eng = _engine(qwen, slots=2)
    reqs = [Request(np.array([i + 1, i + 2], np.int32),
                    SamplingParams(max_new_tokens=3, greedy=True))
            for i in range(5)]
    outs = eng.generate(reqs)
    assert len(outs) == 5 and all(len(o) == 3 for o in outs)
    assert eng.kv.occupancy()["slots_in_use"] == 0      # all retired


# ---------------------------------------------------------------------------
# early exit / token accounting
# ---------------------------------------------------------------------------

def test_token_count_accounting_early_exit(qwen):
    """A short request stops costing decode work when it finishes: total
    decode steps equal the longest request's tail, not the sum."""
    eng = _engine(qwen)
    sched = Scheduler(eng)
    r_short = sched.submit(Request(np.array([1, 2, 3], np.int32),
                                   SamplingParams(max_new_tokens=3,
                                                  greedy=True)))
    r_long = sched.submit(Request(np.array([4, 5, 6, 7], np.int32),
                                  SamplingParams(max_new_tokens=9,
                                                 greedy=True)))
    sched.run()
    assert len(sched.output(r_short)) == 3
    assert len(sched.output(r_long)) == 9
    # first token of each comes from its prefill; the long request then
    # needs 8 decode steps — the seed engine would have burned 9 for BOTH.
    assert sched.decode_steps == 8
    assert eng.decode_steps == 8
    assert sched.finish_reason(r_short) == "length"


def test_eos_early_exit(qwen):
    """Declaring the greedy continuation's 3rd token as EOS cuts the same
    request short with reason 'eos'."""
    prompt = np.array([2, 7, 1], np.int32)
    full = _engine(qwen).generate(
        [Request(prompt, SamplingParams(max_new_tokens=8, greedy=True))])[0]
    eos = int(full[2])
    sched = Scheduler(_engine(qwen))
    rid = sched.submit(Request(prompt, SamplingParams(
        max_new_tokens=8, greedy=True, eos_token=eos)))
    sched.run()
    out = sched.output(rid)
    assert len(out) == 3 and out[-1] == eos
    assert sched.finish_reason(rid) == "eos"


def test_zero_token_budget_emits_nothing(qwen):
    """max_new_tokens=0 returns an empty array (old-generate semantics),
    costs no slot, and doesn't stall the batch it rides in."""
    eng = _engine(qwen)
    outs = eng.generate([
        Request(np.array([1, 2], np.int32),
                SamplingParams(max_new_tokens=0, greedy=True)),
        Request(np.array([3, 4], np.int32),
                SamplingParams(max_new_tokens=3, greedy=True))])
    assert len(outs[0]) == 0
    assert len(outs[1]) == 3
    assert eng.prefill_tokens == 2          # zero-budget request never ran


def test_submit_rejects_overflow(qwen):
    sched = Scheduler(_engine(qwen, seq=16))
    with pytest.raises(ValueError):
        sched.submit(Request(np.arange(10, dtype=np.int32),
                             SamplingParams(max_new_tokens=10)))


# ---------------------------------------------------------------------------
# admission-path hardening (regressions)
# ---------------------------------------------------------------------------

def test_submit_rejects_empty_prompt(qwen):
    """Regression: an empty prompt used to sail through submit() and die
    later on the engine's bare `assert 0 <= start_pos < P`."""
    sched = Scheduler(_engine(qwen))
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(np.empty(0, np.int32),
                             SamplingParams(max_new_tokens=2)))
    # the engine-internal invariant is still an assert
    with pytest.raises(AssertionError):
        _engine(qwen).prefill_into_slot(np.empty(0, np.int32))


def test_temperature_zero_is_greedy_not_inf(qwen):
    """Regression: temperature=0.0 with greedy=False divided logits by
    the 1e-4 clamp and overflowed into categorical; it must sample
    exactly like greedy instead."""
    eng = _engine(qwen)
    logits = np.array([[1.0, 5.0, 2.0], [7.0, -1.0, 3.0]], np.float32)
    toks = eng.sample_tokens(logits, np.zeros(2, np.float32),
                             np.zeros(2, bool))
    np.testing.assert_array_equal(toks, [1, 0])
    # end-to-end: a temperature-0 request matches the greedy run
    prompt = np.array([3, 1, 4, 1, 5], np.int32)
    ref = _engine(qwen).generate(
        [Request(prompt, SamplingParams(max_new_tokens=5, greedy=True))])[0]
    out = _engine(qwen).generate(
        [Request(prompt, SamplingParams(max_new_tokens=5,
                                        temperature=0.0))])[0]
    np.testing.assert_array_equal(out, ref)
    # a hot stochastic row in the same batch must not disturb row 1
    outs = _engine(qwen).generate([
        Request(np.array([7, 7], np.int32),
                SamplingParams(max_new_tokens=5, temperature=5.0)),
        Request(prompt, SamplingParams(max_new_tokens=5, temperature=0.0))])
    np.testing.assert_array_equal(outs[1], ref)


def test_admission_out_of_blocks_requeues_instead_of_dropping(qwen):
    """Regression: `_admit` used to pop the request, pin prefix blocks,
    and let OutOfBlocks from alloc_slot fly — the request vanished
    (output() raised KeyError) and its pins leaked.  With an undersized
    paged pool every submitted request must still complete."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=4,
                        kv_block_size=8, paged=True, num_blocks=4,
                        prefix_cache_blocks=8)
    sched = Scheduler(eng)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 10, dtype=np.int32)
    rids = [sched.submit(Request(
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, 4 + i,
                                             dtype=np.int32)]),
        SamplingParams(max_new_tokens=6, greedy=True))) for i in range(5)]
    sched.run()
    assert sched.admission_stalls > 0          # the bug path was exercised
    for rid in rids:                           # ...and nobody was dropped
        assert len(sched.output(rid)) == 6
    assert eng.kv.pool.in_use == 0
    eng.prefix_cache.evict(10 ** 9)            # all pins released at drain
    assert eng.kv.prefix_pool.in_use == 0


def test_submit_rejects_request_larger_than_pool(qwen):
    """A request that could never fit even alone fails at submit, not as
    an undiagnosable admission deadlock later."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=48, max_slots=2,
                        kv_block_size=8, paged=True, num_blocks=3)
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="never be scheduled"):
        sched.submit(Request(np.arange(30, dtype=np.int32),
                             SamplingParams(max_new_tokens=10)))


# ---------------------------------------------------------------------------
# gateway
# ---------------------------------------------------------------------------

def test_gateway_least_loaded_and_drain(qwen):
    gw = ReplicaGateway.from_engines([_engine(qwen, seed=0),
                                      _engine(qwen, seed=1)])
    handles = [gw.submit(Request(np.array([1 + i, 2, 3], np.int32),
                                 SamplingParams(max_new_tokens=4,
                                                greedy=True)))
               for i in range(6)]
    # least-loaded routing alternates while both replicas are idle
    assert {h[0] for h in handles} == {0, 1}
    assert [r.routed for r in gw.replicas] == [3, 3]
    gw.drain()
    # drain completed every in-flight request
    for h in handles:
        assert len(gw.result(h)) == 4
    assert not gw.has_work
    with pytest.raises(RuntimeError):
        gw.submit(Request(np.array([1], np.int32)))
    tot = gw.stats()["totals"]
    assert tot["requests_completed"] == 6
    assert tot["total_new_tokens"] == 24


def test_gateway_capsule_replicas(qwen, tmp_path):
    """Replicas launched through the ch-run analogue carry capsule
    bookkeeping (image, uid map) from CapsuleRuntime."""
    gw, dep = launch_capsule_replicas(
        2, lambda: _engine(qwen), tmp_path)
    assert all(r.capsule and r.capsule["image"] == "serving-replica"
               and "user namespace" in r.capsule["uid_map"]
               for r in gw.replicas)
    h = gw.submit(Request(np.array([1, 2, 3], np.int32),
                          SamplingParams(max_new_tokens=2, greedy=True)))
    gw.drain()
    assert len(gw.result(h)) == 2


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_metrics_summary_and_export(qwen, tmp_path):
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    sched = Scheduler(_engine(qwen), clock=clock)
    rid = sched.submit(Request(np.array([1, 2], np.int32),
                               SamplingParams(max_new_tokens=3,
                                              greedy=True)))
    sched.run()
    s = sched.metrics.summary()
    assert s["requests_completed"] == 1
    assert s["total_new_tokens"] == 3
    assert s["ttft_ms"]["p50"] > 0
    assert s["latency_ms"]["p95"] >= s["ttft_ms"]["p50"]
    assert s["finish_reasons"] == {"length": 1}
    path = sched.metrics.export(tmp_path / "m.json", arch="qwen2-0.5b")
    import json
    back = json.loads(path.read_text())
    assert back["arch"] == "qwen2-0.5b"
    assert back["requests_completed"] == 1
    assert 0 < back["slot_occupancy"] <= 1
    _ = rid
