"""Fixture suite for repro-lint (src/repro/analysis).

One positive (flagged) and one negative (clean) snippet per rule ID,
the suppression/baseline machinery, and the gate property the CI build
relies on: the full-repo run matches the committed baseline exactly.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import all_rules, lint_paths
from repro.analysis import baseline as bl

REPO_ROOT = Path(__file__).resolve().parents[1]


def _lint_snippet(tmp_path, source, *, rule, name="snippet.py",
                  event_kinds=None):
    """Write one snippet and run a single rule over it."""
    path = tmp_path / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(source)
    rules = [r for r in all_rules() if r.rule_id == rule]
    assert rules, f"unknown rule {rule}"
    return lint_paths([path], root=tmp_path, rules=rules,
                      event_kinds=event_kinds)


# ---------------------------------------------------------------------------
# RL001 — host-device sync in hot paths
# ---------------------------------------------------------------------------

RL001_POS = """
import jax.numpy as jnp

class Scheduler:
    def step(self):
        logits = jnp.dot(self.a, self.b)
        return float(logits)
"""

RL001_NEG = """
import jax.numpy as jnp

class Scheduler:
    def step(self):
        return jnp.dot(self.a, self.b)

class Reporter:
    def summary(self):                 # not reachable from a hot root
        return float(jnp.sum(self.x))
"""


def test_rl001_flags_sync_in_hot_path(tmp_path):
    res = _lint_snippet(tmp_path, RL001_POS, rule="RL001")
    assert len(res.findings) == 1
    f = res.findings[0]
    assert f.rule_id == "RL001" and "float" in f.message
    assert f.line == 7


def test_rl001_clean_hot_path_and_cold_sync_pass(tmp_path):
    res = _lint_snippet(tmp_path, RL001_NEG, rule="RL001")
    assert res.findings == []


def test_rl001_follows_call_graph(tmp_path):
    src = """
import jax

class Scheduler:
    def step(self):
        self.helper()

    def helper(self):
        jax.block_until_ready(self.cache)
"""
    res = _lint_snippet(tmp_path, src, rule="RL001")
    assert len(res.findings) == 1
    assert "block_until_ready" in res.findings[0].message


# ---------------------------------------------------------------------------
# RL002 — recompilation hazards in jitted functions
# ---------------------------------------------------------------------------

RL002_POS = """
import functools
import jax

LOOKUP = {1: 2}

@functools.partial(jax.jit, static_argnames=("mode",))
def f(x, n, mode=[1, 2]):
    if n > 3:
        return x + LOOKUP[1]
    return int(n)
"""

RL002_NEG = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("causal",))
def f(x, mask=None, causal=True):
    if causal:                       # static arg: branch is fine
        x = x + 1
    if mask is not None:             # arity trace: exempt
        x = x * mask
    if x.ndim == 3:                  # shape introspection: exempt
        x = x[0]
    return x
"""


def test_rl002_flags_branch_concretize_mutable(tmp_path):
    res = _lint_snippet(tmp_path, RL002_POS, rule="RL002")
    msgs = [f.message for f in res.findings]
    assert any("branch on runtime value of arg `n`" in m for m in msgs)
    assert any("int(n)" in m for m in msgs)
    assert any("mutable (unhashable) default" in m for m in msgs)
    assert any("closes over mutable `LOOKUP`" in m for m in msgs)
    assert len(res.findings) == 4


def test_rl002_static_none_and_shape_branches_pass(tmp_path):
    res = _lint_snippet(tmp_path, RL002_NEG, rule="RL002")
    assert res.findings == []


def test_rl002_sees_jit_call_sites(tmp_path):
    src = """
import jax

def g(x, n):
    while n > 0:
        x, n = x + 1, n - 1
    return x

g_j = jax.jit(g)
"""
    res = _lint_snippet(tmp_path, src, rule="RL002")
    assert len(res.findings) == 1
    assert "branch on runtime value of arg `n`" in res.findings[0].message


# ---------------------------------------------------------------------------
# RL003 — Pallas launch checks
# ---------------------------------------------------------------------------

RL003_POS = """
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

def launch(x):
    return pl.pallas_call(
        kern,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        scratch_shapes=[pltpu.VMEM(128, jnp.float32)],
    )(x)
"""

RL003_NEG = """
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

def launch(x, interpret=False):
    return pl.pallas_call(
        kern,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((128, 128), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
        interpret=interpret,
    )(x)
"""


def test_rl003_flags_arity_scratch_interpret(tmp_path):
    res = _lint_snippet(tmp_path, RL003_POS, rule="RL003")
    msgs = [f.message for f in res.findings]
    assert any("takes 1 args but the launch grid has rank 2" in m
               for m in msgs)
    assert any("literal tuple" in m for m in msgs)
    assert any("interpret" in m for m in msgs)


def test_rl003_well_formed_launch_passes(tmp_path):
    res = _lint_snippet(tmp_path, RL003_NEG, rule="RL003")
    assert res.findings == []


def test_rl003_scalar_prefetch_extends_index_map_arity(tmp_path):
    src = """
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

def launch(x, interpret=False):
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 8),
        in_specs=[pl.BlockSpec((1, 128), lambda b, i, tbl, lens: (b, 0))],
        out_specs=pl.BlockSpec((1, 128), lambda b, i, tbl, lens: (b, i)),
    )
    return pl.pallas_call(kern, grid_spec=spec, interpret=interpret)(x)
"""
    res = _lint_snippet(tmp_path, src, rule="RL003")
    # 2 grid dims + 2 prefetched scalars = 4 args: both maps are correct
    assert res.findings == []


# ---------------------------------------------------------------------------
# RL004 — tracing-schema drift (scoped to serving/)
# ---------------------------------------------------------------------------

RL004_POS = """
class Tracer:
    def decode(self, rid):
        self._emit("dcode", rid=rid)

class Scheduler:
    def _retire(self, st):
        self.metrics.record_retire(st)
"""

RL004_NEG = """
class Tracer:
    def decode(self, rid):
        self._emit("decode", rid=rid)
"""


def test_rl004_flags_unknown_kind_and_metrics_bypass(tmp_path):
    res = _lint_snippet(tmp_path, RL004_POS, rule="RL004",
                        name="serving/mod.py", event_kinds={"decode"})
    msgs = [f.message for f in res.findings]
    assert any("'dcode' is not in EVENT_KINDS" in m for m in msgs)
    assert any("bypasses the tracer" in m for m in msgs)
    assert len(res.findings) == 2


def test_rl004_known_kind_passes(tmp_path):
    res = _lint_snippet(tmp_path, RL004_NEG, rule="RL004",
                        name="serving/mod.py", event_kinds={"decode"})
    assert res.findings == []


def test_rl004_recovers_event_kinds_from_tree(tmp_path):
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "tracing.py").write_text(
        'EVENT_KINDS = frozenset({"decode", "retire"})\n'
        'class T:\n'
        '    def go(self, rid):\n'
        '        self._emit("retire", rid=rid)\n'
        '        self._emit("dcode", rid=rid)\n')
    rules = [r for r in all_rules() if r.rule_id == "RL004"]
    res = lint_paths([tmp_path], root=tmp_path, rules=rules)
    assert len(res.findings) == 1
    assert "'dcode'" in res.findings[0].message


def test_rl004_recovers_event_kinds_union(tmp_path):
    # the real tracing.py now builds EVENT_KINDS as a union of an inline
    # frozenset and a named one — recovery must resolve the Name half
    (tmp_path / "serving").mkdir()
    (tmp_path / "serving" / "tracing.py").write_text(
        'FAULT_EVENT_KINDS = frozenset({"replica_health"})\n'
        'EVENT_KINDS = frozenset({"decode"}) | FAULT_EVENT_KINDS\n'
        'class T:\n'
        '    def go(self, rid):\n'
        '        self._emit("decode", rid=rid)\n'
        '        self._emit("replica_health", rid=rid)\n'
        '        self._emit("dcode", rid=rid)\n')
    rules = [r for r in all_rules() if r.rule_id == "RL004"]
    res = lint_paths([tmp_path], root=tmp_path, rules=rules)
    assert len(res.findings) == 1
    assert "'dcode'" in res.findings[0].message


def test_rl004_ignores_files_outside_serving(tmp_path):
    res = _lint_snippet(tmp_path, RL004_POS, rule="RL004",
                        name="models/mod.py", event_kinds={"decode"})
    assert res.findings == []


# ---------------------------------------------------------------------------
# RL005 — resource-lifecycle pairing
# ---------------------------------------------------------------------------

RL005_POS = """
class Cache:
    def admit(self, n):
        return self.pool.alloc(n)
"""

RL005_NEG = """
class Cache:
    def admit(self, n):
        return self.pool.alloc(n)

    def evict(self, bid):
        self.pool.free(bid)
"""


def test_rl005_flags_unpaired_alloc(tmp_path):
    res = _lint_snippet(tmp_path, RL005_POS, rule="RL005")
    assert len(res.findings) == 1
    assert "self.pool.alloc" in res.findings[0].message


def test_rl005_paired_alloc_passes(tmp_path):
    res = _lint_snippet(tmp_path, RL005_NEG, rule="RL005")
    assert res.findings == []


def test_rl005_receivers_do_not_cross_pair(tmp_path):
    src = """
class Cache:
    def admit(self, n):
        return self.prefix_pool.alloc(n)    # released by another class

    def evict(self, bid):
        self.pool.free(bid)                 # different receiver
"""
    res = _lint_snippet(tmp_path, src, rule="RL005")
    assert len(res.findings) == 1
    assert "self.prefix_pool.alloc" in res.findings[0].message


# ---------------------------------------------------------------------------
# suppressions + baseline machinery
# ---------------------------------------------------------------------------

def test_inline_suppression_mutes_finding(tmp_path):
    src = RL001_POS.replace("return float(logits)",
                            "return float(logits)  "
                            "# repro-lint: disable=RL001")
    res = _lint_snippet(tmp_path, src, rule="RL001")
    assert res.findings == []
    assert len(res.suppressed) == 1


def test_comment_line_suppression_covers_next_line(tmp_path):
    src = RL001_POS.replace(
        "        return float(logits)",
        "        # deliberate sync  # repro-lint: disable=RL001\n"
        "        return float(logits)")
    res = _lint_snippet(tmp_path, src, rule="RL001")
    assert res.findings == []
    assert len(res.suppressed) == 1


def test_suppression_is_rule_specific(tmp_path):
    src = RL001_POS.replace("return float(logits)",
                            "return float(logits)  "
                            "# repro-lint: disable=RL005")
    res = _lint_snippet(tmp_path, src, rule="RL001")
    assert len(res.findings) == 1


def test_baseline_roundtrip_and_line_drift(tmp_path):
    path = tmp_path / "mod.py"
    path.write_text(RL001_POS)
    rules = [r for r in all_rules() if r.rule_id == "RL001"]
    res = lint_paths([path], root=tmp_path, rules=rules)
    assert len(res.findings) == 1
    base_file = tmp_path / "baseline.json"
    bl.save(base_file, res.findings, res.modules)

    # shift the finding down two lines: fingerprint (text-based) holds
    path.write_text("# a new leading comment\n# another\n" + RL001_POS)
    res2 = lint_paths([path], root=tmp_path, rules=rules)
    new, old, stale = bl.split(res2.findings, bl.load(base_file),
                               res2.modules)
    assert new == [] and len(old) == 1 and stale == []

    # a genuinely new finding is NOT absorbed by the baseline
    path.write_text(RL001_POS + "\nclass S2(Scheduler):\n"
                    "    def decode_once(self):\n"
                    "        return self.x.item()\n")
    res3 = lint_paths([path], root=tmp_path, rules=rules)
    new, old, stale = bl.split(res3.findings, bl.load(base_file),
                               res3.modules)
    assert len(new) == 1 and len(old) == 1
    assert ".item()" in new[0].message


# ---------------------------------------------------------------------------
# the CI gate property: full repo matches the committed baseline
# ---------------------------------------------------------------------------

def test_full_repo_run_matches_committed_baseline():
    res = lint_paths([REPO_ROOT / "src", REPO_ROOT / "benchmarks"],
                     root=REPO_ROOT)
    committed = bl.load(REPO_ROOT / "scripts" / "lint_baseline.json")
    current = sorted(bl.fingerprint(f, res.modules)
                     for f in res.findings)
    assert current == sorted(committed), (
        "repro-lint findings drifted from scripts/lint_baseline.json — "
        "fix the finding, suppress it inline with a justification, or "
        "deliberately run scripts/lint.py --fix-baseline.\n"
        f"current: {current}\nbaseline: {sorted(committed)}")


def test_cli_gate_exits_zero_on_current_tree():
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "lint.py"),
         "src", "benchmarks"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new" in proc.stdout


def test_cli_json_format_and_list_rules(tmp_path):
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "lint.py"),
         "--list-rules"], capture_output=True, text=True)
    assert proc.returncode == 0
    for rid in ("RL001", "RL002", "RL003", "RL004", "RL005"):
        assert rid in proc.stdout

    bad = tmp_path / "serving" / "bad.py"
    bad.parent.mkdir()
    bad.write_text(RL005_POS)
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "scripts" / "lint.py"),
         "--format", "json", "--no-baseline", str(bad)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["summary"]["new"] == 1
    assert payload["findings"][0]["rule"] == "RL005"


def test_cli_fix_baseline_flow(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(RL005_POS)
    base = tmp_path / "baseline.json"
    run = [sys.executable, str(REPO_ROOT / "scripts" / "lint.py"),
           "--baseline", str(base), str(bad)]
    proc = subprocess.run(run, capture_output=True, text=True)
    assert proc.returncode == 1                   # new finding fails
    proc = subprocess.run(run + ["--fix-baseline"],
                          capture_output=True, text=True)
    assert proc.returncode == 0 and base.exists()
    proc = subprocess.run(run, capture_output=True, text=True)
    assert proc.returncode == 0                   # baselined: warns only
    assert "1 baselined" in proc.stdout
