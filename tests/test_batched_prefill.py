"""Paged chunked prefill: the Pallas prefill kernel over block tables +
batched multi-slot co-admission — validated in interpret mode on CPU
with the dense engine / whole-prompt scan as oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.paged_prefill import paged_prefill
from repro.kernels.ref import attention_ref, paged_prefill_ref
from repro.serving import (Request, SamplingParams, Scheduler, ServingEngine)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pages(key, B, C, KV, G, D, NP, page, pps, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, C, KV, G, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KV, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KV, D), dtype)
    tbl = jax.random.randint(ks[3], (B, pps), 0, NP, jnp.int32)
    return q, kp, vp, tbl


# ---------------------------------------------------------------------------
# kernel vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,C,KV,G,D,NP,page,pps,window,softcap", [
    (3, 5, 2, 2, 32, 9, 8, 4, None, None),      # GQA, odd chunk
    (2, 4, 1, 4, 16, 5, 4, 4, 6, None),         # sliding window
    (4, 7, 2, 1, 64, 17, 16, 3, None, 30.0),    # softcap, partial tail
    (1, 3, 1, 1, 8, 2, 4, 2, 3, 10.0),          # window + softcap
])
def test_prefill_kernel_matches_ref(B, C, KV, G, D, NP, page, pps, window,
                                    softcap, rng_key):
    q, kp, vp, tbl = _pages(rng_key, B, C, KV, G, D, NP, page, pps)
    T_ = pps * page
    # starts land mid-page; q_lens include partial (and empty) rows
    start = jnp.array([(5 * b + 3) % (T_ - C) for b in range(B)], jnp.int32)
    qlens = jnp.array([max(0, C - b) for b in range(B)], jnp.int32)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(B, KV, C * G, D)
    out = paged_prefill(qf, kp, vp, tbl, start, qlens, group=G,
                        window=window, softcap=softcap, interpret=True)
    out = out.reshape(B, KV, C, G, D).transpose(0, 2, 1, 3, 4)
    ref = paged_prefill_ref(q, kp, vp, tbl, start, qlens, window=window,
                            softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_prefill_ref_matches_dense_attention(rng_key):
    """Pages laid out by a permutation table reproduce dense contiguous
    causal attention for a mid-sequence query chunk: paging changes
    layout, not math."""
    B, C, KV, G, D, page, pps = 2, 4, 2, 2, 16, 4, 4
    T_ = page * pps
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, C, KV, G, D))
    k = jax.random.normal(ks[1], (B, T_, KV, D))
    v = jax.random.normal(ks[2], (B, T_, KV, D))
    perm = np.random.default_rng(0).permutation(B * pps)
    tbl = jnp.asarray(perm.reshape(B, pps), jnp.int32)
    kp = jnp.zeros((B * pps, page, KV, D))
    vp = jnp.zeros((B * pps, page, KV, D))
    for b in range(B):
        for j in range(pps):
            kp = kp.at[perm[b * pps + j]].set(k[b, j * page:(j + 1) * page])
            vp = vp.at[perm[b * pps + j]].set(v[b, j * page:(j + 1) * page])
    start = jnp.array([6, 9], jnp.int32)
    qlens = jnp.array([C, C], jnp.int32)
    out = paged_prefill_ref(q, kp, vp, tbl, start, qlens)
    for b in range(B):
        s0 = int(start[b])
        L = s0 + C                           # newest attended position + 1
        # fold heads; causal over absolute positions == causal mask on a
        # q chunk placed at the END of the first L keys
        qf = q[b].transpose(1, 2, 0, 3).reshape(KV * G, C, D)
        kf = jnp.repeat(k[b, :L].transpose(1, 0, 2), G, axis=0)
        vf = jnp.repeat(v[b, :L].transpose(1, 0, 2), G, axis=0)
        # attention_ref's causal mask is qpos >= kpos with qpos = row
        # index; shift by padding the q chunk's positions via window-less
        # manual mask instead: compute dense scores directly
        s = jnp.einsum("hqd,hkd->hqk", qf.astype(jnp.float32),
                       kf.astype(jnp.float32)) / np.sqrt(D)
        mask = (jnp.arange(L)[None, :] <= (s0 + jnp.arange(C))[:, None])
        s = jnp.where(mask[None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        refb = jnp.einsum("hqk,hkd->hqd", p, vf.astype(jnp.float32))
        np.testing.assert_allclose(
            np.asarray(out[b].transpose(1, 2, 0, 3).reshape(KV * G, C, D)),
            np.asarray(refb), atol=2e-5, rtol=2e-5)


def test_ops_wrapper_gqa_layout(rng_key):
    """Model layout (B, C, H, D) folds to grouped chunk rows
    consistently."""
    B, C, KV, G, D, NP, page, pps = 2, 3, 2, 3, 16, 7, 4, 3
    q, kp, vp, tbl = _pages(rng_key, B, C, KV, G, D, NP, page, pps)
    start = jnp.array([2, 7], jnp.int32)
    qlens = jnp.array([3, 2], jnp.int32)
    ref = paged_prefill_ref(q, kp, vp, tbl, start, qlens)
    qm = q.reshape(B, C, KV * G, D)
    out = ops.paged_prefill_attention(qm, kp, vp, tbl, start, qlens,
                                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out.reshape(B, C, KV, G, D)), np.asarray(ref),
        atol=2e-6, rtol=2e-6)


def test_kernel_skips_padding_rows_and_garbage_tables(rng_key):
    """q_len = 0 rows return zeros whatever their table holds, and table
    entries past a row's extent (even out-of-range ids) don't change the
    result."""
    B, C, KV, G, D, NP, page, pps = 2, 4, 1, 2, 16, 6, 4, 4
    q, kp, vp, tbl = _pages(rng_key, B, C, KV, G, D, NP, page, pps)
    start = jnp.array([2, 0], jnp.int32)
    qlens = jnp.array([4, 0], jnp.int32)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(B, KV, C * G, D)
    base = paged_prefill(qf, kp, vp, tbl, start, qlens, group=G,
                         interpret=True)
    assert not np.asarray(base[1]).any()               # padding row: zeros
    junk = tbl.at[0, 3].set(99999).at[1, 0].set(-5)    # past row 0's extent
    out = paged_prefill(qf, kp, vp, junk, start, qlens, group=G,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


# ---------------------------------------------------------------------------
# chunked prefill vs one-shot oracle (dense AND paged, with resume)
# ---------------------------------------------------------------------------

def _oneshot_last_logits(eng, prompt):
    """The whole-prompt scan — the pre-chunking reference prefill."""
    from repro.models import transformer as T
    cache = T.init_cache(eng.cfg, 1, eng.max_seq_len)
    _, _, ref = eng._prefill(eng.params, jnp.asarray(prompt)[None],
                             cache, None)
    return np.asarray(ref[0])


@pytest.mark.parametrize("chunk", [1, 5, 16])
@pytest.mark.parametrize("paged", [False, True])
def test_chunked_prefill_matches_oneshot(qwen, chunk, paged):
    """Odd prompt lengths x chunk sizes x start_pos resume offsets: the
    chunked path (dense scan or paged kernel) reproduces the one-shot
    prefill — greedy-identical first tokens, near-identical logits."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=64, max_slots=2,
                        kv_block_size=8, prefill_chunk=chunk, paged=paged,
                        prefix_cache_blocks=16)
    pc = eng.prefix_cache
    for plen in (3, 7, 17, 29):
        prompt = ((np.arange(plen) * 5 + 2) % cfg.vocab_size).astype(np.int32)
        slot, last = eng.prefill_into_slot(prompt)
        ref = _oneshot_last_logits(eng, prompt)
        assert int(np.argmax(last)) == int(np.argmax(ref))
        np.testing.assert_allclose(last, ref, atol=3e-2, rtol=3e-2)
        # start_pos resume: insert this prompt, then prefill a sibling
        # sharing all but the final token (resume offset = cached match)
        pc.insert(prompt, slot)
        sib = np.concatenate(
            [prompt[:plen - 1],
             [(int(prompt[-1]) + 1) % cfg.vocab_size, 3, 9]]
        ).astype(np.int32)
        cached, blocks = pc.lookup(sib)
        assert cached > 0
        slot2, last2 = eng.prefill_into_slot(sib, start_pos=cached,
                                             prefix_blocks=blocks)
        ref2 = _oneshot_last_logits(eng, sib)
        assert int(np.argmax(last2)) == int(np.argmax(ref2))
        np.testing.assert_allclose(last2, ref2, atol=3e-2, rtol=3e-2)
        pc.release(blocks)
        eng.free_slot(slot)
        eng.free_slot(slot2)


@pytest.mark.parametrize("chunk", [5, 16])
def test_generate_greedy_bit_identical_dense_vs_paged_vs_serial(qwen, chunk):
    """End-to-end greedy outputs are bit-identical across the dense
    layout, batched paged co-admission, and one-at-a-time paged
    admission."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (5, 13, 3, 21, 7, 9)]
    sps = [SamplingParams(max_new_tokens=m, greedy=True)
           for m in (6, 4, 7, 3, 5, 6)]

    def serve(paged, prefill_batch, serial=False):
        eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=4,
                            kv_block_size=8, prefill_chunk=chunk,
                            paged=paged, prefill_batch=prefill_batch)
        sched = Scheduler(eng, max_admissions_per_step=1 if serial else None)
        rids = [sched.submit(Request(p, sp))
                for p, sp in zip(prompts, sps)]
        sched.run()
        return [sched.output(r) for r in rids]

    dense = serve(False, 4)
    batched = serve(True, 4)
    serial = serve(True, 1, serial=True)
    for a, b, c in zip(dense, batched, serial):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen3-moe-30b-a3b"])
def test_paged_prefill_window_softcap_families(arch):
    """gemma2 (sliding window + logit softcaps + local/global pattern)
    and MoE route through the paged-prefill kernel bit-identically."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (5, 11, 17)]
    sps = [SamplingParams(max_new_tokens=4, greedy=True)] * 3

    def serve(paged):
        # raw argmax (eps=0): softcaps compress the logit spectrum, so a
        # 1e-2 tie set puts tokens at its boundary where dense/paged
        # summation noise flips membership — the bit-identity this test
        # pins is the stronger property for these workloads
        eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=3,
                            kv_block_size=8, paged=paged,
                            greedy_tie_eps=0.0)
        sched = Scheduler(eng)
        rids = [sched.submit(Request(p, sp))
                for p, sp in zip(prompts, sps)]
        sched.run()
        return [sched.output(r) for r in rids]

    for a, b in zip(serve(False), serve(True)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# no dense stripe / telemetry
# ---------------------------------------------------------------------------

def test_paged_prefill_allocates_no_dense_stripe(qwen, monkeypatch):
    """Acceptance: a paged prefill of a max_seq_len-length prompt never
    materializes the dense batch-1 stripe — T.init_cache is not called,
    the transient-bytes telemetry stays zero, and the resident KV bytes
    are exactly the preallocated pool blocks."""
    import repro.models.transformer as T
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=64, max_slots=2,
                        kv_block_size=16, paged=True)
    pool_bytes = eng.kv.kv_bytes()

    def boom(*a, **k):
        raise AssertionError("dense stripe allocated during paged prefill")

    monkeypatch.setattr(T, "init_cache", boom)
    prompt = (np.arange(64, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    slot, last = eng.prefill_into_slot(prompt.astype(np.int32))
    assert last is not None and last.shape == (cfg.vocab_size,)
    assert eng.transient_prefill_bytes == 0
    assert eng.kv.kv_bytes() == pool_bytes   # pool blocks only, no stripe
    eng.free_slot(slot)


def test_prefill_padding_accounting(qwen):
    """real vs executed vs padding: one wave of the compiled (Bp, C)
    program runs rounds * C * Bp token positions; the split shows up in
    the engine counters and the metrics summary."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=4,
                        kv_block_size=8, prefill_chunk=16, paged=True,
                        prefill_batch=4)
    sched = Scheduler(eng)
    for n in (7, 9, 20):
        sched.submit(Request(
            ((np.arange(n) * 7 + 1) % cfg.vocab_size).astype(np.int32),
            SamplingParams(max_new_tokens=1, greedy=True)))
    sched.run()
    # one wave, rounds = ceil(20/16) = 2 -> 2 * 16 * 4 = 128 executed
    assert eng.prefill_tokens == 36
    assert eng.prefill_tokens_executed == 128
    assert eng.prefill_tokens_padding == 92
    s = sched.metrics.summary()["prefill_tokens"]
    assert s == {"real": 36, "executed": 128, "padding": 92,
                 "padding_fraction": 92 / 128}


def test_decode_once_keeps_logits_on_device(qwen):
    """The decode-step logits stay device-resident; the host transfer is
    deferred to sample_tokens (one sync per step, not two)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=2,
                        kv_block_size=8)
    slot, _ = eng.prefill_into_slot(np.array([1, 2, 3], np.int32))
    logits = eng.decode_once(np.zeros(2, np.int32),
                             np.array([3, 0], np.int32))
    assert isinstance(logits, jax.Array)
    toks = eng.sample_tokens(logits, np.zeros(2, np.float32),
                             np.ones(2, bool))
    assert toks.shape == (2,) and toks.dtype.kind == "i"
    eng.free_slot(slot)


def test_capped_admission_first_token_retire_is_not_deadlock(qwen):
    """Regression: with max_admissions_per_step=1, a request that
    retires at its first sampled token leaves no active sequence while
    the queue is non-empty — that's a capped-but-progressing round, not
    an admission deadlock."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=2,
                        kv_block_size=8)
    sched = Scheduler(eng, max_admissions_per_step=1)
    rids = [sched.submit(Request(np.array([1 + i, 2, 3], np.int32),
                                 SamplingParams(max_new_tokens=1,
                                                greedy=True)))
            for i in range(3)]
    sched.run()                              # used to raise RuntimeError
    for r in rids:
        assert len(sched.output(r)) == 1


def test_prefill_into_slots_all_or_nothing(qwen):
    """A co-admission batch that cannot fully allocate releases every
    slot it claimed before OutOfBlocks propagates."""
    from repro.serving import OutOfBlocks
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=4,
                        kv_block_size=8, paged=True, num_blocks=3)
    prompts = [np.arange(1, 9, dtype=np.int32),      # 1 block
               np.arange(1, 17, dtype=np.int32),     # 2 blocks
               np.arange(1, 10, dtype=np.int32)]     # 2 blocks -> dry
    with pytest.raises(OutOfBlocks):
        eng.prefill_into_slots(prompts)
    assert eng.kv.pool.in_use == 0
    assert eng.kv.free_slot_count == 4
