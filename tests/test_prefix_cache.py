"""Prefix-cache subsystem: refcounted KV block sharing, the radix index,
chunked prefill, and the end-to-end bit-identity + FLOPs-saved contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (KVBlockPool, OutOfBlocks, PagedKVCache,
                           PrefixCache, ReplicaGateway, Request,
                           SamplingParams, Scheduler, ServingEngine)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, slots=2, seq=128, seed=0, prefix_blocks=64, chunk=8):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         rng_seed=seed, kv_block_size=8,
                         prefix_cache_blocks=prefix_blocks,
                         prefill_chunk=chunk)


def _prompt(*chunks):
    return np.concatenate([np.asarray(c, np.int32) for c in chunks])


SYS = np.arange(1, 68, dtype=np.int32) % 50            # 67-token "system prompt"


# ---------------------------------------------------------------------------
# KVBlockPool refcount invariants
# ---------------------------------------------------------------------------

def test_pool_ref_unref_lifecycle():
    pool = KVBlockPool(num_blocks=4, block_size=8)
    b = pool.alloc()
    assert pool.refcount(b) == 1
    assert pool.ref(b) == 2
    assert pool.unref(b) == 1
    assert pool.in_use == 1                 # still held by the first ref
    assert pool.unref(b) == 0
    assert pool.in_use == 0 and pool.available == 4
    with pytest.raises(AssertionError):     # double-unref is a hard error
        pool.unref(b)
    with pytest.raises(AssertionError):     # ref of a dead block too
        pool.ref(b)


def test_pool_free_of_shared_block_is_error():
    pool = KVBlockPool(num_blocks=2, block_size=8)
    b = pool.alloc()
    pool.ref(b)
    with pytest.raises(AssertionError):     # free() requires exclusivity
        pool.free([b])
    pool.unref(b)
    pool.free([b])                          # exclusive again -> fine
    assert pool.available == 2


def test_pool_fork_requires_live_source():
    pool = KVBlockPool(num_blocks=3, block_size=8)
    src = pool.alloc()
    dst = pool.fork(src)
    assert dst != src and pool.refcount(dst) == 1
    pool.free([dst])
    pool.free([src])
    with pytest.raises(AssertionError):     # fork-after-free is a hard error
        pool.fork(src)


# ---------------------------------------------------------------------------
# Prefix store: physical save / load / fork
# ---------------------------------------------------------------------------

def test_store_save_load_roundtrip_and_fork(qwen):
    cfg, params = qwen
    eng = _engine(qwen, slots=1, seq=64)
    kv = eng.kv
    prompt = _prompt(np.arange(10, 26))                # 16 tokens, 2 blocks
    slot, _ = eng.prefill_into_slot(prompt)

    b0 = kv.save_prefix_block(slot, 0)
    b1 = kv.save_prefix_block(slot, 8)
    fresh = jax.tree.map(jnp.copy,
                         __import__("repro.models.transformer",
                                    fromlist=["x"]).init_cache(cfg, 1, 64))
    loaded = kv.load_prefix_blocks(fresh, [b0, b1])

    # recompute the same prompt from scratch: positions [0, 16) must match
    eng2 = _engine(qwen, slots=1, seq=64, prefix_blocks=0)
    slot2, _ = eng2.prefill_into_slot(prompt)
    for l_load, l_ref, bax, sax in zip(jax.tree.leaves(loaded),
                                       jax.tree.leaves(eng2.kv.cache),
                                       kv._axes, kv._seq_axes):
        got = jnp.take(l_load, 0, axis=bax)
        want = jnp.take(l_ref, slot2, axis=bax)
        sl = [slice(None)] * got.ndim
        sl[sax - 1 if sax > bax else sax] = slice(0, 16)
        np.testing.assert_array_equal(np.asarray(got[tuple(sl)]),
                                      np.asarray(want[tuple(sl)]))

    # fork: private physical copy, independent id
    f0 = kv.fork_prefix_block(b0)
    assert f0 != b0
    for leaf, bax in zip(jax.tree.leaves(kv.prefix_store), kv._axes):
        np.testing.assert_array_equal(
            np.asarray(jnp.take(leaf, f0, axis=bax)),
            np.asarray(jnp.take(leaf, b0, axis=bax)))


# ---------------------------------------------------------------------------
# Radix tree: insert / match / split / evict
# ---------------------------------------------------------------------------

def test_radix_insert_and_match(qwen):
    eng = _engine(qwen, slots=1)
    pc = eng.prefix_cache
    prompt = _prompt(SYS, [60, 61, 62])
    slot, _ = eng.prefill_into_slot(prompt)
    assert pc.insert(prompt, slot) == len(prompt)

    # exact-prefix probe (peek: no refs, no LRU touch)
    assert pc.peek(prompt) == len(prompt) - 1          # capped at P-1
    assert pc.peek(_prompt(SYS)) == len(SYS) - 1
    assert pc.peek(_prompt(SYS, [60, 61, 62, 63])) == len(prompt)
    assert pc.peek(_prompt([9, 9, 9])) == 0

    # lookup pins the matched blocks
    cached, blocks = pc.lookup(_prompt(SYS, [60, 61, 62, 63]))
    assert cached == len(prompt)
    assert all(pc.pool.refcount(b) >= 2 for b in blocks)
    pc.release(blocks)
    assert all(pc.pool.refcount(b) == 1 for b in blocks)


def test_radix_mid_edge_divergence_splits_and_forks(qwen):
    eng = _engine(qwen, slots=2)
    pc = eng.prefix_cache
    a = _prompt(SYS, [60, 61])
    slot, _ = eng.prefill_into_slot(a)
    pc.insert(a, slot)
    nodes_before = pc.num_nodes()

    # diverges inside SYS (position 30 — mid-block with block_size 8)
    b = _prompt(SYS[:30], [70, 71, 72])
    cached, blocks = pc.lookup(b)
    assert cached == 30
    slot_b, _ = eng.prefill_into_slot(b, start_pos=cached,
                                      prefix_blocks=blocks)
    pc.insert(b, slot_b)
    assert pc.num_nodes() == nodes_before + 2          # split + new leaf
    assert pc.stats.forked_blocks >= 1                 # COW on block 30//8

    # both branches still match in full
    assert pc.peek(a) == len(a) - 1
    assert pc.peek(b) == len(b) - 1
    pc.release(blocks)


def test_eviction_is_lru_and_never_reclaims_referenced_blocks(qwen):
    # pool of 4 blocks; each 16-token prompt needs 2
    eng = _engine(qwen, slots=2, prefix_blocks=4)
    pc = eng.prefix_cache
    p1 = _prompt(np.full(16, 7))
    p2 = _prompt(np.full(16, 9))
    s1, _ = eng.prefill_into_slot(p1)
    pc.insert(p1, s1)
    s2, _ = eng.prefill_into_slot(p2)
    pc.insert(p2, s2)
    assert pc.pool.available == 0

    # pin p1's blocks like a running request, then touch p1 (p2 becomes LRU)
    cached, pinned = pc.lookup(p1)
    assert cached == 15

    p3 = _prompt(np.full(16, 3))
    eng.free_slot(s1)
    s3, _ = eng.prefill_into_slot(p3)
    pc.insert(p3, s3)                      # must evict -> only p2 evictable
    assert pc.peek(p1) == 15               # pinned + recently used: survives
    assert pc.peek(p2) == 0                # LRU victim
    assert pc.peek(p3) == 15               # newly cached
    assert pc.stats.evicted_blocks == 2

    # pinned blocks stayed live through eviction pressure
    assert all(pc.pool.refcount(b) >= 1 for b in pinned)
    pc.release(pinned)


def test_insert_skips_when_everything_is_pinned(qwen):
    eng = _engine(qwen, slots=2, prefix_blocks=2)
    pc = eng.prefix_cache
    p1 = _prompt(np.full(16, 7))
    s1, _ = eng.prefill_into_slot(p1)
    pc.insert(p1, s1)
    _, pinned = pc.lookup(p1)              # pin both blocks
    p2 = _prompt(np.full(16, 9))
    s2, _ = eng.prefill_into_slot(p2)
    assert pc.insert(p2, s2) == 0          # nothing evictable -> no caching
    assert pc.peek(p1) == 15               # cache intact
    pc.release(pinned)


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt_scan(qwen):
    """Chunked prefill (padding included) is bit-identical to the
    whole-prompt scan for prompt lengths around the chunk boundary."""
    cfg, params = qwen
    from repro.models import transformer as T
    eng = _engine(qwen, slots=1, prefix_blocks=0, chunk=8)
    for plen in (5, 8, 13, 16, 17):
        prompt = (np.arange(plen) * 3 + 1).astype(np.int32) % 50
        slot, last = eng.prefill_into_slot(prompt)
        cache = T.init_cache(cfg, 1, eng.max_seq_len)
        _, _, ref = eng._prefill(params, jnp.asarray(prompt)[None],
                                 cache, None)
        np.testing.assert_array_equal(last, np.asarray(ref[0]))
        eng.free_slot(slot)
    # one compiled program regardless of prompt length
    assert eng.prefill_tokens_executed == sum(-(-n // 8) * 8
                                              for n in (5, 8, 13, 16, 17))


# ---------------------------------------------------------------------------
# End-to-end: bit-identity + saved prefill work
# ---------------------------------------------------------------------------

def test_outputs_bit_identical_with_cache_on_vs_off(qwen):
    reqs = [Request(_prompt(SYS, np.full(5, 60 + i)),
                    SamplingParams(max_new_tokens=4, greedy=True))
            for i in range(4)]
    off = _engine(qwen, prefix_blocks=0).generate(reqs)
    on_eng = _engine(qwen, prefix_blocks=64)
    on = on_eng.generate(reqs)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    # the shared 67-token prefix was only computed once
    assert on_eng.cached_prefix_tokens > 0
    assert on_eng.prefill_tokens < sum(len(r.prompt) for r in reqs)


def test_scheduler_counts_hits_and_releases_pins(qwen):
    eng = _engine(qwen)
    sched = Scheduler(eng)
    for i in range(3):
        sched.submit(Request(_prompt(SYS, [90 + i]),
                             SamplingParams(max_new_tokens=2, greedy=True)))
    sched.run()
    s = sched.metrics.summary()["prefix_cache"]
    assert s["hits"] == 2 and s["misses"] == 1
    assert s["cached_tokens_served"] > 0
    # all request pins released at retire: every block back to tree-only
    pc = eng.prefix_cache
    leaves = pc._leaves()
    assert leaves and all(pc._evictable(n) for n in leaves)


def test_multi_turn_chat_reuses_growing_history(qwen):
    """Turn k's prompt extends turn k-1's — each admission recomputes only
    the new tail, not the conversation so far."""
    eng = _engine(qwen, slots=1, seq=128)
    sched = Scheduler(eng)
    history = _prompt(SYS)
    recomputed = []
    for turn in range(3):
        history = _prompt(history, np.full(6, 80 + turn))
        before = eng.prefill_tokens
        rid = sched.submit(Request(history.copy(),
                                   SamplingParams(max_new_tokens=2,
                                                  greedy=True)))
        sched.run()
        recomputed.append(eng.prefill_tokens - before)
        history = _prompt(history, sched.output(rid))
    assert recomputed[0] == len(SYS) + 6        # cold first turn
    assert max(recomputed[1:]) <= 16            # warm turns: tail only


def test_ssm_family_degrades_gracefully(qwen):
    """A non-positional cache family leaves the prefix cache disabled but
    serves fine through the same scheduler path."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("mamba2-1.3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq_len=32, max_slots=2,
                        prefix_cache_blocks=32, prefill_chunk=8)
    assert eng.prefix_cache is None
    outs = eng.generate([Request(np.array([1, 2, 3], np.int32),
                                 SamplingParams(max_new_tokens=3,
                                                greedy=True))])
    assert len(outs[0]) == 3


# ---------------------------------------------------------------------------
# Gateway prefix affinity
# ---------------------------------------------------------------------------

def test_gateway_routes_shared_prefix_to_owner(qwen):
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, seed=0), _engine(qwen, seed=1)])
    sp = SamplingParams(max_new_tokens=2, greedy=True)
    handles = []
    for i in range(4):
        handles.append(gw.submit(Request(_prompt(SYS, [70 + i]), sp)))
        gw.run()                            # complete before the next turn
    # every request after the first found the warm replica
    owners = {h[0] for h in handles}
    assert len(owners) == 1
    rep = gw.replicas[owners.pop()]
    s = rep.scheduler.metrics.summary()["prefix_cache"]
    assert s["hits"] == 3
    tot = gw.stats()["totals"]["prefix_cache"]
    assert tot["hits"] == 3 and tot["cached_tokens_served"] > 0


def test_gateway_affinity_yields_to_load(qwen):
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, seed=0), _engine(qwen, seed=1)], affinity_slack=0)
    sp = SamplingParams(max_new_tokens=2, greedy=True)
    # saturate whichever replica owns the hash of this prefix
    first = gw.submit(Request(_prompt(SYS, [1]), sp))[0]
    routed = {gw.submit(Request(_prompt(SYS, [2 + i]), sp))[0]
              for i in range(3)}
    # with zero slack, queued load on the owner pushes traffic over
    assert routed - {first}, "affinity never yielded to load"
    gw.drain()
