"""Mamba2 / SSD tests: chunked scan vs naive recurrence, decode consistency,
property-based invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models import ssm as S
from repro.models import transformer as T


def naive_recurrence(x, dt, A, B, C):
    b, S_, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bf = np.repeat(np.asarray(B, np.float64), rep, 2)
    Cf = np.repeat(np.asarray(C, np.float64), rep, 2)
    xf, dtf, Af = (np.asarray(v, np.float64) for v in (x, dt, A))
    h = np.zeros((b, H, N, P))
    ys = []
    for t in range(S_):
        dec = np.exp(dtf[:, t] * Af[None])
        h = dec[:, :, None, None] * h + np.einsum(
            "bh,bhn,bhp->bhnp", dtf[:, t], Bf[:, t], xf[:, t])
        ys.append(np.einsum("bhn,bhnp->bhp", Cf[:, t], h))
    return np.stack(ys, 1), h


def _random_ssd_inputs(key, b, S_, H, P, G, N):
    ks = jax.random.split(key, 5)
    return (jax.random.normal(ks[0], (b, S_, H, P)),
            jax.nn.softplus(jax.random.normal(ks[1], (b, S_, H))),
            -jnp.exp(jax.random.normal(ks[2], (H,))),
            jax.random.normal(ks[3], (b, S_, G, N)),
            jax.random.normal(ks[4], (b, S_, G, N)))


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_vs_naive(chunk, rng_key):
    x, dt, A, B, C = _random_ssd_inputs(rng_key, 2, 32, 4, 8, 2, 16)
    y, h = S.ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, h_ref = naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-3)


def test_chunk_size_invariance(rng_key):
    x, dt, A, B, C = _random_ssd_inputs(rng_key, 1, 64, 2, 4, 1, 8)
    y16, _ = S.ssd_chunked(x, dt, A, B, C, 16)
    y64, _ = S.ssd_chunked(x, dt, A, B, C, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=1e-3)


def test_decode_step_matches_scan(rng_key):
    x, dt, A, B, C = _random_ssd_inputs(rng_key, 2, 16, 4, 8, 2, 8)
    y_ref, _ = S.ssd_chunked(x, dt, A, B, C, 8)
    h = jnp.zeros((2, 4, 8, 8))
    ys = []
    for t in range(16):
        y1, h = S.ssd_decode_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-3)


def test_initial_state_threading(rng_key):
    """ssd(x, s0=h1) over the 2nd half == 2nd half of ssd over the whole."""
    x, dt, A, B, C = _random_ssd_inputs(rng_key, 1, 32, 2, 4, 1, 8)
    y_full, h_full = S.ssd_chunked(x, dt, A, B, C, 8)
    y1, h1 = S.ssd_chunked(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
    y2, h2 = S.ssd_chunked(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], 8,
                           initial_state=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 16:]),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), s=st.sampled_from([8, 16, 24]))
def test_ssd_decay_property(seed, s):
    """With C==B one-hot-ish and A very negative, the state forgets:
    output at t is dominated by the most recent input."""
    key = jax.random.PRNGKey(seed)
    x, dt, A, B, C = _random_ssd_inputs(key, 1, s, 2, 4, 1, 4)
    # guarantee dt*A <= -50 everywhere so one step erases the state
    dt = dt + 0.5
    A_fast = -(jnp.abs(A) + 1.0) * 100.0
    y_fast, _ = S.ssd_chunked(x, dt, A_fast, B, C, 8)
    # each step's output must equal the single-step (memoryless) response
    y_memless = []
    for t in range(s):
        h0 = jnp.zeros((1, 2, 4, 4))
        y1, _ = S.ssd_decode_step(h0, x[:, t], dt[:, t], A_fast,
                                  B[:, t], C[:, t])
        y_memless.append(y1)
    ref = np.asarray(jnp.stack(y_memless, 1))
    np.testing.assert_allclose(np.asarray(y_fast), ref,
                               atol=1e-3 * (1.0 + np.abs(ref).max()))


def test_mamba2_block_decode_matches_prefill(rng_key):
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=11,
                      ssm_state=8, ssm_head_dim=16, ssm_chunk=8,
                      dtype="float32")
    params = S.init_mamba2(rng_key, cfg)
    B, S_ = 2, 12
    x = jax.random.normal(jax.random.fold_in(rng_key, 7), (B, S_, 32)) * 0.5
    full, _ = S.mamba2_block(params, cfg, x)
    cache = S.init_mamba2_cache(cfg, B)
    outs = []
    for t in range(S_):
        o, cache = S.mamba2_block(params, cfg, x[:, t:t + 1], cache=cache)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-3)
