"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
in interpret mode (CPU) per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, rmsnorm_ref, ssd_scan_ref
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan
from repro.models.ssm import ssd_chunked

ATT_CASES = [
    # (BH, Sq, Skv, D, causal, window, softcap, dtype)
    (4, 128, 128, 64, True, None, None, jnp.float32),
    (2, 256, 256, 64, True, None, 50.0, jnp.float32),
    (2, 256, 256, 128, True, 64, None, jnp.float32),
    (2, 128, 128, 64, True, 32, 30.0, jnp.float32),
    (3, 100, 100, 64, True, None, None, jnp.float32),      # non-multiples
    (2, 128, 384, 64, False, None, None, jnp.float32),     # cross
    (1, 1, 256, 64, True, None, None, jnp.float32),        # decode
    (2, 128, 128, 64, True, None, None, jnp.bfloat16),
    (1, 64, 192, 32, True, 16, None, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,Sq,Skv,D,causal,window,softcap,dtype", ATT_CASES)
def test_flash_attention_sweep(BH, Sq, Skv, D, causal, window, softcap,
                               dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (BH, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (BH, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (BH, Skv, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(block_q, block_k, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 256, 64))
    k = jax.random.normal(ks[1], (2, 256, 64))
    v = jax.random.normal(ks[2], (2, 256, 64))
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_gqa_wrapper_matches_model_attention(rng_key):
    from repro.models.attention import attend
    Bz, Sq, H, KV, D = 2, 128, 8, 2, 64
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (Bz, Sq, H, D))
    k = jax.random.normal(ks[1], (Bz, Sq, KV, D))
    v = jax.random.normal(ks[2], (Bz, Sq, KV, D))
    out = ops.mha_flash_attention(q, k, v, causal=True, interpret=True)
    ref = attend(q.reshape(Bz, Sq, KV, H // KV, D), k, v,
                 scale=1 / np.sqrt(D), causal=True).reshape(Bz, Sq, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


SSD_CASES = [
    # (BH, S, P, N, chunk, dtype)
    (4, 64, 32, 16, 16, jnp.float32),
    (2, 128, 64, 32, 32, jnp.float32),
    (2, 64, 64, 128, 64, jnp.float32),
    (1, 96, 32, 16, 32, jnp.float32),
    (2, 64, 32, 16, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,S,P,N,chunk,dtype", SSD_CASES)
def test_ssd_scan_sweep(BH, S, P, N, chunk, dtype, rng_key):
    ks = jax.random.split(rng_key, 5)
    x = jax.random.normal(ks[0], (BH, S, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)))
    B = jax.random.normal(ks[3], (BH, S, N), jnp.float32).astype(dtype)
    C = jax.random.normal(ks[4], (BH, S, N), jnp.float32).astype(dtype)
    out = ssd_scan(x, dt, A, B, C, chunk, interpret=True)
    # oracle: per-bh single-head ssd_chunked (itself validated vs the naive
    # recurrence in test_ssm.py)
    outs = []
    for i in range(BH):
        y, _ = ssd_chunked(x[i][None, :, None, :], dt[i][None, :, None],
                           A[i][None], B[i][None, :, None, :],
                           C[i][None, :, None, :], chunk)
        outs.append(y[0, :, 0])
    ref = jnp.stack(outs)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_ssd_ops_wrapper_gqa_groups(rng_key):
    b, S, H, P, G, N = 2, 64, 4, 32, 2, 16
    ks = jax.random.split(rng_key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    y = ops.ssd(x, dt, A, B, C, chunk=16, interpret=True)
    y_ref, _ = ssd_chunked(x, dt, A, B, C, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)


@pytest.mark.parametrize("shape,dtype", [
    ((64, 128), jnp.float32), ((3, 37, 128), jnp.bfloat16),
    ((2, 7, 11, 256), jnp.float32), ((1, 512), jnp.bfloat16)])
def test_rmsnorm_sweep(shape, dtype, rng_key):
    x = jax.random.normal(rng_key, shape, jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(rng_key, 1),
                          (shape[-1],)) * 0.1
    out = rmsnorm_kernel(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("BH,Sq,Skv,D,causal,window", [
    (2, 1, 256, 64, True, None),        # decode one-token
    (2, 128, 128, 64, True, None),
    (1, 1, 300, 128, True, 64),         # windowed decode, non-multiple
])
def test_flash_attention_int8kv(BH, Sq, Skv, D, causal, window, rng_key):
    """Fused-dequant int8-KV flash kernel == oracle on dequantized k/v."""
    from repro.kernels.flash_attention import flash_attention_int8kv
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (BH, Sq, D))
    k = jax.random.normal(ks[1], (BH, Skv, D))
    v = jax.random.normal(ks[2], (BH, Skv, D))

    def quant(x):
        s = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-8
        return jnp.round(x / s[..., None]).astype(jnp.int8), s

    k8, ksc = quant(k)
    v8, vsc = quant(v)
    out = flash_attention_int8kv(q, k8, ksc, v8, vsc, causal=causal,
                                 window=window, interpret=True)
    ref = attention_ref(q, k8.astype(jnp.float32) * ksc[..., None],
                        v8.astype(jnp.float32) * vsc[..., None],
                        causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
