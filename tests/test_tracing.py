"""Request-lifecycle tracing: event schema, ring buffer, preemption
observability, Chrome/JSONL exporters, gateway trace merge, and the
``merge_summaries`` edge-case contract.

Also hosts the executable form of the ROADMAP near-tie caveat: a
slow-marked sweep asserting that any paged-vs-dense greedy divergence
happens only at near-tie top-2 logits (page-wise online-softmax
summation order), never at a decisive argmax.
"""
import json

import jax
import numpy as np
import pytest

from repro.serving import (EVENT_KINDS, ReplicaGateway, Request,
                           SamplingParams, Scheduler, ServingEngine,
                           Tracer, export_chrome_trace, merge_summaries,
                           merge_traces, to_chrome_trace, validate_event)


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-0.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(qwen, *, slots=3, seq=48, block=8, chunk=8, prefill_batch=2,
            **kw):
    cfg, params = qwen
    return ServingEngine(cfg, params, max_seq_len=seq, max_slots=slots,
                         kv_block_size=block, prefill_chunk=chunk,
                         prefill_batch=prefill_batch, **kw)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, n, dtype=np.int32)


def _serve_traced(qwen, prompts, max_news, **eng_kw):
    tracer = Tracer(enabled=True)
    sched = Scheduler(_engine(qwen, **eng_kw), tracer=tracer)
    cfg, _ = qwen
    rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=m,
                                                   greedy=True)))
            for p, m in zip(prompts, max_news)]
    sched.run()
    return tracer, sched, rids


# ---------------------------------------------------------------------------
# tracer mechanics (no model needed)
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_no_events_but_feeds_metrics():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(enabled=False, clock=clock)
    tr.submit(0)
    tr.first_token(0)
    tr.retire(0, 5, "length")
    tr.prefix_probe(1, 4, 10)
    assert len(tr.events) == 0 and tr.emitted_events == 0
    s = tr.metrics.summary()
    assert s["requests_completed"] == 1
    assert s["prefix_cache"]["hits"] == 1
    assert s["prefix_cache"]["cached_tokens_served"] == 4


def test_ring_buffer_bounds_and_counts_drops():
    tr = Tracer(enabled=True, buffer_events=4, clock=lambda: 0.0)
    for rid in range(10):
        tr.submit(rid)
    assert len(tr.events) == 4
    assert tr.emitted_events == 10 and tr.dropped_events == 6
    assert [ev["rid"] for ev in tr.events] == [6, 7, 8, 9]  # oldest drop
    with pytest.raises(ValueError, match="buffer_events"):
        Tracer(buffer_events=0)


def test_event_schema_and_validator():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(enabled=True, clock=clock)
    tr.submit(3)
    tr.bind_slot(0, 3)
    tr.block_alloc(0, 2, 10)           # resolves rid through the binding
    tr.engine_step(decoded=False, queue_depth=1, active=0, max_slots=2,
                   admitted=0, completed=0, prefill_executed=0, budget=None,
                   dur_admit_s=0.0, dur_prefill_s=0.0, dur_decode_s=0.0,
                   dur_sample_s=0.0, free_blocks=10, free_slots=2,
                   inflight=0, prefix_pins=0)
    evs = tr.snapshot()
    assert [e["kind"] for e in evs] == ["submit", "block_alloc",
                                       "engine_step"]
    assert evs[1]["rid"] == 3
    assert evs[0]["ts"] < evs[1]["ts"] < evs[2]["ts"]   # monotonic clock
    assert evs[0]["step"] == 0 and tr.current_step == 1  # step advanced
    for ev in evs:
        assert ev["kind"] in EVENT_KINDS
        assert validate_event(ev) is None
    # the validator actually rejects malformed events
    assert validate_event({"kind": "submit", "rid": 1}) is not None  # no ts
    assert validate_event({"ts": 1.0, "kind": "nope", "step": 0}) is not None
    assert validate_event({"ts": 1.0, "kind": "submit", "step": 0}) \
        is not None                     # request-scoped kind without rid
    assert validate_event({"ts": 1.0, "kind": "engine_step"}) is not None
    # gauges only sampled on decoded steps (pre-tracing semantics)
    assert tr.metrics.decode_steps == 0


def test_unknown_kind_cannot_be_exported_silently(tmp_path):
    tr = Tracer(enabled=True, clock=lambda: 1.0)
    tr.submit(0)
    path = tr.export_jsonl(tmp_path / "t.jsonl")
    [line] = path.read_text().splitlines()
    ev = json.loads(line)
    assert ev["replica"] == "replica0"       # exporter stamps the replica
    assert validate_event(ev) is None


# ---------------------------------------------------------------------------
# traced serving runs
# ---------------------------------------------------------------------------

def test_traced_run_covers_lifecycle_and_validates(qwen, tmp_path):
    cfg, _ = qwen
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng, cfg, n) for n in (5, 19, 11)]
    tracer, sched, rids = _serve_traced(qwen, prompts, [4, 2, 3],
                                        paged=True, prefix_cache_blocks=16)
    evs = tracer.snapshot()
    for ev in evs:
        assert validate_event(ev) is None, ev
    kinds = {e["kind"] for e in evs}
    assert {"submit", "prefix_probe", "admit", "prefill_advance",
            "first_token", "decode", "retire", "block_alloc", "block_free",
            "prefix_insert", "engine_step"} <= kinds
    for rid in rids:
        span = [e["kind"] for e in evs if e.get("rid") == rid]
        assert span[0] == "submit" and span[-1] == "retire"
        assert "first_token" in span
        # submit < admit < first_token < retire within the span
        order = [span.index(k) for k in ("submit", "admit", "first_token",
                                         "retire")]
        assert order == sorted(order)
    # one engine_step per scheduler step, step ids dense from 0
    steps = [e for e in evs if e["kind"] == "engine_step"]
    assert [e["step"] for e in steps] == list(range(len(steps)))
    assert sum(1 for e in steps if e["decoded"]) == \
        sched.metrics.decode_steps
    # phase durations are sane: all non-negative, and on decoded steps
    # the decode dispatch took measurable time
    for e in steps:
        for k in ("dur_admit_s", "dur_prefill_s", "dur_decode_s",
                  "dur_sample_s"):
            assert e[k] >= 0.0
    # JSONL round-trips through the file exporter
    path = tracer.export_jsonl(tmp_path / "run.jsonl")
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == len(evs)
    assert all(validate_event(e) is None for e in lines)


def test_preempted_request_trace_and_single_count(qwen):
    """The regression satellite: a recompute-preempted request's span
    shows preempt -> re-admit (``resumed=True``) -> resume-from-prefix
    (warm ``prefix_probe``) in that order, while the metrics still
    count exactly one submit and one finish for it."""
    cfg, _ = qwen
    rng = np.random.default_rng(3)
    shared = _prompt(rng, cfg, 16)
    p_a = np.concatenate([shared, _prompt(rng, cfg, 7)])
    p_b = np.concatenate([shared, _prompt(rng, cfg, 21)])
    # same geometry as the interleaved preemption test: A's decode
    # growth past pos 24 forces the pool dry while B is mid-prefill
    tracer = Tracer(enabled=True)
    eng = _engine(qwen, paged=True, num_blocks=8, chunk=4,
                  prefix_cache_blocks=16)
    sched = Scheduler(eng, prefill_token_budget=8, tracer=tracer)
    r_a = sched.submit(Request(p_a, SamplingParams(max_new_tokens=12,
                                                   greedy=True)))
    while not sched.active:
        sched.step()
    r_b = sched.submit(Request(p_b, SamplingParams(max_new_tokens=2,
                                                   greedy=True)))
    sched.run()
    assert sched.preemptions >= 1

    evs = [e for e in tracer.snapshot() if e.get("rid") == r_b]
    kinds = [e["kind"] for e in evs]
    assert kinds.count("submit") == 1 and kinds.count("retire") == 1
    i_admit0 = kinds.index("admit")
    assert evs[i_admit0]["resumed"] is False
    i_pre = kinds.index("preempt")
    assert evs[i_pre]["mid_prefill"] is True
    # the re-admission comes after the preemption, flagged resumed, and
    # its probe hit the prefix A's completed prefill had cached
    i_admit1 = next(i for i in range(i_pre, len(kinds))
                    if kinds[i] == "admit")
    assert evs[i_admit1]["resumed"] is True
    i_probe1 = next(i for i in range(i_pre, len(kinds))
                    if kinds[i] == "prefix_probe")
    assert i_pre < i_probe1 < i_admit1
    assert evs[i_probe1]["hit"] and evs[i_probe1]["cached_len"] >= 16
    assert kinds.index("submit") < i_admit0 < i_pre < i_admit1 \
        < kinds.index("retire")

    # metrics: one submit / one finish per request despite the cycle
    s = sched.metrics.summary()
    assert s["requests_completed"] == 2
    assert len(sched.metrics._submit) == 2
    assert len(sched.metrics._finish) == 2
    # the pool-dry admission stall was recorded with its cause
    stalls = [e for e in tracer.snapshot()
              if e["kind"] == "admission_stall"]
    oob = [e for e in tracer.snapshot() if e["kind"] == "out_of_blocks"]
    assert stalls or oob
    _ = r_a


def test_chrome_trace_spans_and_counters(qwen, tmp_path):
    cfg, _ = qwen
    rng = np.random.default_rng(1)
    tracer, _sched, rids = _serve_traced(
        qwen, [_prompt(rng, cfg, 6), _prompt(rng, cfg, 13)], [3, 2],
        paged=True)
    doc = to_chrome_trace({tracer.name: tracer.snapshot()})
    evs = doc["traceEvents"]
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert names == {"replica0"}
    for rid in rids:
        span = f"replica0/req{rid}"
        sevs = [e for e in evs if e.get("id") == span]
        phs = [e["ph"] for e in sevs]
        assert phs[0] == "b" and phs[-1] == "e"
        assert phs.count("n") >= 4           # submit/admit/decode/retire
        assert all(e["ts"] >= 0 for e in sevs)
    assert any(e["ph"] == "X" and e["cat"] == "engine" for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "free_blocks" for e in evs)
    # the exporter writes valid JSON
    path = export_chrome_trace({tracer.name: tracer.snapshot()},
                               tmp_path / "t.chrome.json")
    assert json.loads(path.read_text())["traceEvents"]


def test_gateway_merges_replica_traces_on_shared_clock(qwen, tmp_path):
    cfg, _ = qwen
    rng = np.random.default_rng(2)
    gw = ReplicaGateway.from_engines(
        [_engine(qwen, paged=True, prefix_cache_blocks=16)
         for _ in range(2)], tracing=True)
    handles = [gw.submit(Request(_prompt(rng, cfg, 9),
                                 SamplingParams(max_new_tokens=2,
                                                greedy=True)))
               for _ in range(4)]
    gw.drain()
    assert {h[0] for h in handles} == {0, 1}     # both replicas used
    merged = gw.trace_events()
    assert {e["replica"] for e in merged} == {"replica0", "replica1"}
    ts = [e["ts"] for e in merged]
    assert ts == sorted(ts)                      # one shared timeline
    # the routing decision was traced with a reason on the target replica
    routes = [e for e in merged if e["kind"] == "route"]
    assert len(routes) == 4
    assert all(e["reason"] in ("prefix_affinity", "hash_owner",
                               "least_loaded") for e in routes)
    # exporters: merged JSONL validates; chrome has 2 processes
    jsonl = gw.export_trace_jsonl(tmp_path / "gw.jsonl")
    for line in jsonl.read_text().splitlines():
        assert validate_event(json.loads(line)) is None
    chrome = json.loads(
        gw.export_chrome_trace(tmp_path / "gw.chrome.json").read_text())
    pids = {e["pid"] for e in chrome["traceEvents"]}
    assert len(pids) == 2
    # merge_traces on an explicit tracer list matches the gateway view
    assert merge_traces(gw.tracers) == merged


def test_tracing_is_inert_on_outputs(qwen):
    """Turning tracing on must not perturb the computation: greedy
    outputs bit-identical traced vs untraced."""
    cfg, _ = qwen
    rng = np.random.default_rng(4)
    prompts = [_prompt(rng, cfg, n) for n in (7, 21)]

    def serve(tracer):
        sched = Scheduler(_engine(qwen, paged=True), tracer=tracer)
        rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=3,
                                                       greedy=True)))
                for p in prompts]
        sched.run()
        return [sched.output(r) for r in rids]

    for a, b in zip(serve(None), serve(Tracer(enabled=True))):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# merge_summaries edge-case contract (satellite)
# ---------------------------------------------------------------------------

def _no_nans(obj):
    if isinstance(obj, dict):
        return all(_no_nans(v) for v in obj.values())
    if isinstance(obj, (int, float)):
        return obj == obj                    # NaN != NaN
    return True


def test_merge_summaries_empty_returns_sentinel():
    assert merge_summaries([]) == {"replicas": 0}


def test_merge_summaries_single_replica_passthrough():
    from repro.serving import ServingMetrics
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    m = ServingMetrics(clock=clock)
    m.record_submit(0)
    m.record_first_token(0)
    m.record_finish(0, 4, "length")
    s = m.summary()
    merged = merge_summaries([s])
    assert merged["replicas"] == 1
    assert merged["requests_completed"] == 1
    assert merged["total_new_tokens"] == 4
    assert merged["ttft_ms_p95"] == s["ttft_ms"]["p95"]
    assert merged["latency_ms_p95"] == s["latency_ms"]["p95"]
    assert _no_nans(merged)


def test_merge_summaries_idle_fleet_no_nan():
    from repro.serving import ServingMetrics
    idle = [ServingMetrics(clock=lambda: 0.0).summary() for _ in range(3)]
    merged = merge_summaries(idle)
    assert merged["replicas"] == 3
    assert merged["requests_completed"] == 0
    assert merged["ttft_ms_p95"] == 0.0
    assert _no_nans(merged)


def test_merge_summaries_partial_dicts_do_not_raise():
    merged = merge_summaries([{"requests_completed": 2},
                              {"total_new_tokens": 7}])
    assert merged["replicas"] == 2
    assert merged["requests_completed"] == 2
    assert merged["total_new_tokens"] == 7
    assert _no_nans(merged)


# ---------------------------------------------------------------------------
# ROADMAP carry-over, made executable: the near-tie argmax caveat
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_vs_dense_divergence_only_at_near_ties(qwen):
    """The known caveat (see ROADMAP.md): the paged decode kernel's
    page-wise online-softmax summation order can legitimately flip a
    greedy argmax against the dense path when the top-2 logits are a
    near-tie (~1e-3 apart).  This pins the caveat down as a property:
    wherever paged and dense greedy outputs diverge on random
    workloads, the dense logits at the first divergent position must be
    a near-tie between the two chosen tokens — a decisive-argmax
    divergence would be a real kernel bug, and fails here.

    The tie-break is now ON by default (``greedy_tie_eps=1e-2``), so
    this test arms ``greedy_tie_eps=0.0`` explicitly: it exercises the
    raw-argmax opt-out path, which is where the caveat still lives."""
    cfg, _ = qwen
    NEAR_TIE = 1e-2                    # generous bound over the ~1e-3 seen
    divergences = 0
    for seed in (31, 32, 33):
        rng = np.random.default_rng(seed)
        prompts = [_prompt(rng, cfg, int(rng.integers(3, 24)))
                   for _ in range(4)]
        max_news = [int(rng.integers(2, 8)) for _ in prompts]

        def serve(paged):
            sched = Scheduler(_engine(qwen, paged=paged,
                                      greedy_tie_eps=0.0))
            rids = [sched.submit(Request(p, SamplingParams(
                max_new_tokens=m, greedy=True)))
                for p, m in zip(prompts, max_news)]
            sched.run()
            return [sched.output(r) for r in rids]

        dense_outs = serve(False)
        paged_outs = serve(True)
        for prompt, d_out, p_out in zip(prompts, dense_outs, paged_outs):
            if np.array_equal(d_out, p_out):
                continue
            divergences += 1
            j = int(np.argmax(np.asarray(d_out) != np.asarray(p_out)))
            # recompute the logits that produced position j with a
            # fresh dense prefill of prompt + the agreed tokens
            agreed = np.concatenate(
                [prompt, np.asarray(d_out[:j], np.int32)])
            ref_eng = _engine(qwen)
            slot, logits = ref_eng.prefill_into_slots([agreed])[0]
            ref_eng.free_slot(slot)
            logits = np.asarray(logits, np.float64)
            top2 = np.sort(logits)[-2:]
            gap = float(top2[1] - top2[0])
            assert gap < NEAR_TIE, (
                f"seed {seed}: paged/dense diverged at pos {j} with a "
                f"DECISIVE top-2 logit gap {gap:.4f} (dense tok "
                f"{d_out[j]}, paged tok {p_out[j]}) — not the near-tie "
                f"caveat, a real kernel divergence")
            # both chosen tokens sit within the near-tie band of the max
            for tok in (int(d_out[j]), int(p_out[j])):
                assert logits.max() - logits[tok] < NEAR_TIE
    # zero divergences is fine: the caveat is probabilistic.  The test's
    # value is that any divergence that does occur is proven benign.


@pytest.mark.slow
def test_greedy_tie_eps_makes_layouts_bit_identical(qwen):
    """The caveat retired (ROADMAP carry-over): with the deterministic
    tie-break epsilon armed, greedy argmax picks the lowest token id
    within eps of the max, so the paged kernel's page-order summation
    noise (~1e-3, well inside eps=1e-2) can no longer flip a near-tie —
    the exact same workloads as the divergence test above must now be
    bit-identical across layouts."""
    cfg, _ = qwen
    TIE_EPS = 1e-2                     # matches the NEAR_TIE bound above
    for seed in (31, 32, 33):
        rng = np.random.default_rng(seed)
        prompts = [_prompt(rng, cfg, int(rng.integers(3, 24)))
                   for _ in range(4)]
        max_news = [int(rng.integers(2, 8)) for _ in prompts]

        def serve(paged):
            sched = Scheduler(_engine(qwen, paged=paged,
                                      greedy_tie_eps=TIE_EPS))
            rids = [sched.submit(Request(p, SamplingParams(
                max_new_tokens=m, greedy=True)))
                for p, m in zip(prompts, max_news)]
            sched.run()
            return [sched.output(r) for r in rids]

        dense_outs = serve(False)
        paged_outs = serve(True)
        for i, (d_out, p_out) in enumerate(zip(dense_outs, paged_outs)):
            assert np.array_equal(d_out, p_out), (
                f"seed {seed} request {i}: paged/dense greedy outputs "
                f"still diverge with greedy_tie_eps={TIE_EPS} "
                f"(dense {list(d_out)}, paged {list(p_out)})")
