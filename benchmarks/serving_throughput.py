"""Serving throughput benchmark: continuous batching vs run-to-max.

Drives a mixed workload (varied prompt lengths, varied ``max_new_tokens``,
mixed greedy/stochastic sampling) through the replica gateway and records
the scheduler telemetry — tokens/s, TTFT and latency percentiles, queue
depth, slot occupancy, decode-step accounting — to ``BENCH_serving.json``.

The headline number continuous batching earns: ``decode_steps`` equals
the *longest* request's tail, not requests x global max, because retired
sequences free their slots (and KV blocks) mid-decode for queued
admissions.

  PYTHONPATH=src python -m benchmarks.serving_throughput          # smoke
  PYTHONPATH=src python -m benchmarks.serving_throughput --full
"""
from __future__ import annotations

import argparse


def run(quick: bool = True, out_path: str = "BENCH_serving.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ReplicaGateway, Request, SamplingParams, ServingEngine

    arch = "qwen2-0.5b"
    n_requests = 8 if quick else 32
    replicas = 2
    max_slots = 2 if quick else 4
    max_seq_len = 64 if quick else 128

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engines = [ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=max_slots, rng_seed=r)
               for r in range(replicas)]
    gateway = ReplicaGateway.from_engines(engines)

    rng = np.random.default_rng(0)
    handles = []
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)),
                              dtype=np.int32)
        sp = SamplingParams(max_new_tokens=int(rng.integers(4, 17)),
                            greedy=bool(i % 2),
                            temperature=0.8)
        handles.append(gateway.submit(Request(prompt, sp)))
    gateway.drain()

    stats = gateway.stats()
    tot = stats["totals"]
    # accounting sanity: every request got exactly its own budget
    emitted = sum(len(gateway.result(h)) for h in handles)
    assert emitted == tot["total_new_tokens"], (emitted, tot)

    record = {"arch": arch, "quick": quick, "n_requests": n_requests,
              "max_slots_per_replica": max_slots, **stats}
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("serving/tokens_per_s", 0.0,
         f"{tot['tokens_per_s']:.1f} tok/s over {replicas} replicas "
         f"({n_requests} reqs, {tot['total_new_tokens']} tokens)"),
        ("serving/ttft_p95", tot["ttft_ms_p95"] * 1e3,
         "time to first token (one prefill, not one full batch)"),
        ("serving/latency_p95", tot["latency_ms_p95"] * 1e3,
         "request completion latency"),
        ("serving/decode_steps", float(tot["decode_steps"]),
         f"continuous batching: slot occupancy "
         f"{tot['slot_occupancy']:.2f}, results -> {out_path}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
