"""Paper §II-H: Horovod allreduce vs TensorFlow parameter servers.

Compiles the SAME training step under both collective strategies on an
8-rank host mesh and compares per-rank collective bytes from the HLO:
ring allreduce moves O(2·P) per rank; the PS pattern's all-gather +
broadcast moves O(N·P) — the measured contrast that motivated Horovod.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import List, Tuple

_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.core import hvd, paramserver
from repro.launch.mesh import make_mesh
from repro import optim
from repro.launch.dryrun import collective_bytes
cfg = ModelConfig(name="t", family="dense", num_layers=4, d_model=256,
                  num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=32000)
key = jax.random.PRNGKey(0)
mesh = make_mesh(({ranks},), ("data",))
opt = optim.rmsprop(1e-3)
loss_fn = lambda p, b: T.lm_loss(p, cfg, b)
p_s = jax.eval_shape(lambda k: T.init_params(cfg, k), key)
s_s = jax.eval_shape(opt.init, p_s)
B = {ranks} * 2
b_s = {{"tokens": jax.ShapeDtypeStruct((B, 128), jnp.int32),
       "labels": jax.ShapeDtypeStruct((B, 128), jnp.int32)}}
n_params = sum(int(jnp.prod(jnp.asarray(l.shape))) for l in jax.tree.leaves(p_s))
for name, maker in [("hvd", hvd.make_train_step),
                    ("ps", paramserver.make_train_step)]:
    step = maker(loss_fn, opt, mesh, donate=False)
    c = step.lower(p_s, s_s, b_s).compile()
    cb = collective_bytes(c.as_text())
    print(f"RES {{name}} {{sum(cb.values())}} {{n_params}}")
"""


def run(ranks: int = 8) -> List[Tuple[str, float, str]]:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROG.format(ranks=ranks)],
                       capture_output=True, text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    res = {}
    n_params = 0
    for line in r.stdout.splitlines():
        if line.startswith("RES"):
            _, name, nbytes, npar = line.split()
            res[name] = int(nbytes)
            n_params = int(npar)
    grad_bytes = n_params * 4
    rows = [
        (f"hvd_allreduce/{ranks}ranks", 0.0,
         f"{res['hvd']:,} B/rank ({res['hvd']/grad_bytes:.2f}x grad bytes)"),
        (f"paramserver/{ranks}ranks", 0.0,
         f"{res['ps']:,} B/rank ({res['ps']/grad_bytes:.2f}x grad bytes)"),
        ("ps_vs_hvd_ratio", 0.0,
         f"{res['ps']/max(res['hvd'],1):.2f}x more collective traffic "
         f"(paper: why Horovod replaced parameter servers)"),
    ]
    assert res["ps"] > res["hvd"], "PS must move more bytes than allreduce"
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
