"""Reproduce the §Perf hillclimb measurements (EXPERIMENTS.md).

Re-lowers every (baseline, iteration) configuration of the three
hillclimbed pairs and prints the roofline terms, so the §Perf tables are
regenerable from source:

  PYTHONPATH=src python -m benchmarks.hillclimb [--pair A|B|C|A3]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_PROG = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp
from repro import optim
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import (_compile_costs, _group_counts,
                                 collective_bytes, collective_bytes_by_scope)
from repro.distributed import stepfn

def terms(cfg, shape_name, strategy, **step_kw):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    G, cfg1, cfg2 = _group_counts(cfg)
    out = []
    for c in (cfg1, cfg2):
        c = c.with_(scan_layers=False, attn_q_chunk=0)
        if step_kw:
            jitted, structs, _ = stepfn.make_train_step(
                c, optim.adamw(1e-4), mesh, strategy, shape, **step_kw)
        else:
            jitted, structs = stepfn.make_step_for_shape(c, mesh, strategy, shape)
        with mesh, jax.transfer_guard("disallow"):
            comp = jitted.lower(*structs).compile()
        cost = comp.cost_analysis()
        out.append((float(cost.get("flops", 0)),
                    float(cost.get("bytes accessed", 0)),
                    float(sum(collective_bytes(comp.as_text()).values()))))
    ex = lambda i: out[0][i] + (G - 1) * (out[1][i] - out[0][i])
    return {"compute_ms": ex(0)/197e12*1e3, "memory_ms": ex(1)/819e9*1e3,
            "collective_ms": ex(2)/50e9*1e3}

def emit(pair, name, t):
    print("ROW " + json.dumps({"pair": pair, "iter": name, **t}), flush=True)

pair = os.environ.get("HILLCLIMB_PAIR", "all")

if pair in ("A", "all"):
    q = get_config("qwen2-0.5b")
    emit("A", "A0 pure DP", terms(q, "train_4k", "dp"))
    emit("A", "A1 dp_tp (refuted)", terms(q, "train_4k", "dp_tp"))
    emit("A", "A2 DP + chunked CE",
         terms(q, "train_4k", "dp", loss_variant="chunked_ce"))

if pair in ("B", "all"):
    d = get_config("dbrx-132b")
    emit("B", "B0 per-seq groups",
         terms(d.with_(moe_group_size=1), "decode_32k", "fsdp_tp"))
    emit("B", "B1 adaptive groups", terms(d, "decode_32k", "fsdp_tp"))
    emit("B", "B2 groups of 8 (refuted)",
         terms(d.with_(moe_group_size=8), "decode_32k", "fsdp_tp"))
    emit("B", "B3 + int8 KV cache",
         terms(d.with_(kv_cache_dtype="int8"), "decode_32k", "fsdp_tp"))

if pair in ("C", "all"):
    m = get_config("qwen3-moe-30b-a3b")
    emit("C", "C0 baseline", terms(m, "train_4k", "fsdp_tp"))
    emit("C", "C1 cf=1.05",
         terms(m.with_(moe_capacity_factor=1.05), "train_4k", "fsdp_tp"))
    emit("C", "C2 remat=dots",
         terms(m.with_(remat_policy="dots"), "train_4k", "fsdp_tp"))
    emit("C", "C3 buffer shard (refuted)",
         terms(m.with_(remat_policy="dots", moe_buffer_shard="model"),
               "train_4k", "fsdp_tp"))

if pair in ("A3", "all"):
    # multi-pod hierarchical allreduce: inter-pod bytes, flat vs hier
    from repro.models import transformer as T
    from repro.core import hvd
    cfg = get_config("qwen2-0.5b")
    mesh = make_production_mesh(multi_pod=True)
    opt = optim.rmsprop(1e-3)
    loss_fn = lambda p, b: T.lm_loss(p, cfg, b)
    key = jax.random.PRNGKey(0)
    p_s = jax.eval_shape(lambda k: T.init_params(cfg, k), key)
    s_s = jax.eval_shape(opt.init, p_s)
    b_s = {"tokens": jax.ShapeDtypeStruct((512, 2048), jnp.int32),
           "labels": jax.ShapeDtypeStruct((512, 2048), jnp.int32)}
    for name, hier in [("A3 flat allreduce", False),
                       ("A3 hierarchical", True)]:
        step = hvd.make_train_step(loss_fn, opt, mesh,
                                   axes=("pod", "data", "model"),
                                   hierarchical=hier, donate=False)
        with mesh:
            comp = step.lower(p_s, s_s, b_s).compile()
        scope = collective_bytes_by_scope(comp.as_text(), pod_size=256)
        print("ROW " + json.dumps(
            {"pair": "A3", "iter": name,
             "intra_pod_GB": scope["intra_pod"]/1e9,
             "inter_pod_GB": scope["inter_pod"]/1e9}), flush=True)
"""


def run(pair: str = "all"):
    env = dict(os.environ, PYTHONPATH="src", HILLCLIMB_PAIR=pair)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                       text=True, env=env)
    rows = [json.loads(l[4:]) for l in r.stdout.splitlines()
            if l.startswith("ROW ")]
    if r.returncode != 0 and not rows:
        raise RuntimeError(r.stderr[-2000:])
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all", choices=["A", "B", "C", "A3",
                                                      "all"])
    args = ap.parse_args()
    for row in run(args.pair):
        print(row)


if __name__ == "__main__":
    main()
