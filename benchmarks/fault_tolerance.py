"""Fault-tolerance benchmark: kill 1 of 3 replicas mid-burst.

The fleet claim behind PR 9, measured end to end: a 3-replica gateway
serving a request burst loses one replica to an injected crash partway
through, salvages its queued + in-flight requests, re-routes them to the
survivors under the retry policy — and **every** request still completes
with greedy outputs bit-identical to a fault-free run of the same
workload (``greedy_tie_eps`` armed, so the changed batch composition
after failover cannot flip a near-tie argmax).

Written to ``BENCH_faults.json`` (validated by ``benchmarks/run.py
--check``):

* ``requests_completed == n_requests`` and ``failed_requests == 0`` —
  the kill loses zero requests;
* ``salvage_success_rate == 1.0`` — every salvaged (retried) request
  completed on a survivor;
* ``bit_identical_outputs`` — fleet-under-fault outputs equal the
  fault-free oracle's, token for token;
* ``recovery_wall_s`` — failover event to last salvaged completion;
* the merged trace timeline is exported to
  ``results/trace_faults.jsonl`` for ``scripts/trace_report.py
  --faults``.

  PYTHONPATH=src python -m benchmarks.fault_tolerance          # smoke
  PYTHONPATH=src python -m benchmarks.fault_tolerance --full
"""
from __future__ import annotations

import argparse
import os
import time

KILLED = "replica1"
CRASH_STEP = 4
TIE_EPS = 1e-2
TRACE_OUT = os.path.join("results", "trace_faults.jsonl")


def _workload(cfg, n):
    import numpy as np

    from repro.serving import Request, SamplingParams
    rng = np.random.default_rng(17)
    return [Request(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 16)), dtype=np.int32),
                    SamplingParams(max_new_tokens=int(rng.integers(4, 9)),
                                   greedy=True))
            for _ in range(n)]


def run(quick: bool = True, out_path: str = "BENCH_faults.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import (FaultPlan, FaultSpec, ReplicaGateway,
                               RequestFailed, Scheduler, ServingEngine)
    from repro.serving.health import DEAD

    arch = "qwen2-0.5b"
    block, max_seq_len, slots, prefill_batch, chunk = 16, 64, 4, 2, 8
    n_requests = 12 if quick else 18

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    num_blocks = slots * (max_seq_len // block)

    def engine():
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=slots, kv_block_size=block,
                             prefill_chunk=chunk,
                             prefill_batch=prefill_batch,
                             paged=True, num_blocks=num_blocks,
                             greedy_tie_eps=TIE_EPS)

    reqs = _workload(cfg, n_requests)

    # fault-free oracle: the same workload on one unharmed replica
    oracle_sched = Scheduler(engine())
    oracle_rids = [oracle_sched.submit(r) for r in reqs]
    oracle_sched.run()
    oracle = [oracle_sched.output(r) for r in oracle_rids]

    # the fleet under fault: replica1 crashes at its 5th step, squarely
    # mid-burst — in-flight decodes and queued admissions both salvage
    plan = FaultPlan([FaultSpec(kind="crash", replica=KILLED,
                                at_step=CRASH_STEP)])
    gw = ReplicaGateway.from_engines([engine() for _ in range(3)],
                                     tracing=True, fault_plan=plan)

    t0 = time.perf_counter()
    handles = [gw.submit(r) for r in reqs[: 2 * n_requests // 3]]
    for _ in range(CRASH_STEP + 2):        # let the crash land mid-burst
        gw.step()
    handles += [gw.submit(r) for r in reqs[2 * n_requests // 3:]]
    gw.drain()
    wall = time.perf_counter() - t0

    assert gw.health[1].state == DEAD, "the injected crash never fired"
    stats = gw.stats()
    fleet = stats["fleet"]
    assert fleet["failovers"] == 1

    completed = failed = 0
    bit_identical = True
    for h, ref in zip(handles, oracle):
        out = gw.result(h)
        if isinstance(out, RequestFailed):
            failed += 1
            continue
        completed += 1
        if not np.array_equal(out, ref):
            bit_identical = False
    assert completed == n_requests, (
        f"{n_requests - completed} request(s) lost to the kill")
    assert failed == 0
    assert bit_identical, "failover changed greedy outputs"

    salvaged = [r for r in gw._requests.values() if r.attempts > 0]
    assert salvaged, "the kill salvaged nothing — crash landed too late"
    salvage_ok = sum(1 for r in salvaged if r.output is not None)
    salvage_rate = salvage_ok / len(salvaged)
    assert salvage_rate == 1.0, (
        f"only {salvage_ok}/{len(salvaged)} salvaged requests completed")

    # recovery wall: the failover event to the last salvaged retire
    events = gw.trace_events()
    fo_ts = next(e["ts"] for e in events if e["kind"] == "replica_failover")
    retried_rids = {(e["replica"], e["rid"]) for e in events
                    if e["kind"] == "replica_retry"}
    recovery_wall = max(
        (e["ts"] for e in events if e["kind"] == "retire"
         and (e["replica"], e["rid"]) in retried_rids),
        default=fo_ts) - fo_ts

    tot = stats["totals"]
    assert tot["requests_submitted"] == n_requests, (
        "retries double-counted as logical submits")
    assert tot["requests_completed"] == n_requests
    assert tot["requests_retried"] == len(salvaged)

    os.makedirs(os.path.dirname(TRACE_OUT), exist_ok=True)
    gw.export_trace_jsonl(TRACE_OUT)

    record = {
        "arch": arch, "quick": quick, "n_requests": n_requests,
        "replicas": 3, "killed_replica": KILLED,
        "crash_at_step": CRASH_STEP,
        "greedy_tie_eps": TIE_EPS,
        "block_size": block, "max_seq_len": max_seq_len,
        "max_slots": slots, "num_blocks": num_blocks,
        "requests_completed": completed,
        "failed_requests": failed,
        "salvaged_requests": len(salvaged),
        "salvage_success_rate": salvage_rate,
        "retries": tot["requests_retried"],
        "failovers": fleet["failovers"],
        "bit_identical_outputs": bit_identical,
        "wall_s": wall,
        "recovery_wall_s": recovery_wall,
        "health": fleet["health"],
        "trace_out": TRACE_OUT,
    }
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("fault_tolerance/kill_1_of_3", wall * 1e6,
         f"{n_requests} requests, {KILLED} crashed at step {CRASH_STEP}: "
         f"{completed} completed, {failed} failed, "
         f"{len(salvaged)} salvaged @ {salvage_rate:.0%}, "
         f"bit-identical to fault-free oracle, results -> {out_path}"),
        ("fault_tolerance/recovery", recovery_wall * 1e6,
         f"failover -> last salvaged completion: {recovery_wall:.3f}s "
         f"({tot['requests_retried']} retried), trace -> {TRACE_OUT}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
