"""Prefix-cache benchmark: cold vs. warm TTFT and recomputed prefill work.

The workload the subsystem exists for: ``n_requests`` prompts sharing one
64+-token system prompt, each with a distinct user suffix, served one
after another through the continuous-batching scheduler.

* **cold** — prefix cache disabled: every request replays the full
  prompt through prefill.
* **warm** — prefix cache enabled: the first request populates the radix
  tree; every later request loads the shared prefix's KV blocks from the
  store and prefills only its suffix chunks.

Reports wall-clock TTFT and *prefill tokens actually executed* per
request (the FLOPs proxy: every executed token is one ``decode_step``
pass), asserts the greedy outputs are bit-identical between the two
engines, and writes ``BENCH_prefix_cache.json``.

  PYTHONPATH=src python -m benchmarks.prefix_cache          # smoke
  PYTHONPATH=src python -m benchmarks.prefix_cache --full
"""
from __future__ import annotations

import argparse


def _serve_sequentially(engine, prompts, max_new):
    """One request at a time through a scheduler; returns per-request
    (ttft_s, executed_prefill_tokens) plus the greedy outputs."""
    import numpy as np

    from repro.serving import Request, SamplingParams, Scheduler
    sched = Scheduler(engine)
    ttfts, executed, outs = [], [], []
    for p in prompts:
        before = engine.prefill_tokens_executed
        rid = sched.submit(Request(p, SamplingParams(max_new_tokens=max_new,
                                                     greedy=True)))
        sched.run()
        ttfts.append(sched.metrics._first[rid] - sched.metrics._submit[rid])
        executed.append(engine.prefill_tokens_executed - before)
        outs.append(sched.output(rid))
    return ttfts, executed, outs, sched.metrics.summary()["prefix_cache"]


def run(quick: bool = True, out_path: str = "BENCH_prefix_cache.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ServingEngine

    arch = "qwen2-0.5b"
    n_requests = 8
    system_len = 72 if quick else 256
    suffix_len = 8
    max_new = 4 if quick else 16
    max_seq_len = 128 if quick else 512

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    def engine(prefix_blocks):
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=2, kv_block_size=16,
                             prefix_cache_blocks=prefix_blocks,
                             prefill_chunk=16)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, system_len, dtype=np.int32)
    prompts = [np.concatenate([system,
                               rng.integers(0, cfg.vocab_size, suffix_len,
                                            dtype=np.int32)])
               for _ in range(n_requests)]

    cold_ttft, cold_exec, cold_out, _ = _serve_sequentially(
        engine(0), prompts, max_new)
    warm_eng = engine(max_seq_len // 16 * 4)
    warm_ttft, warm_exec, warm_out, pc = _serve_sequentially(
        warm_eng, prompts, max_new)

    for a, b in zip(cold_out, warm_out):
        np.testing.assert_array_equal(a, b)

    # "warm" = steady state: every request after the one that populated
    # the tree; "cold" averages the cache-disabled engine over the same
    warm_ttft_ms = sum(warm_ttft[1:]) / (n_requests - 1) * 1e3
    cold_ttft_ms = sum(cold_ttft) / n_requests * 1e3
    warm_tokens = sum(warm_exec[1:]) / (n_requests - 1)
    cold_tokens = sum(cold_exec) / n_requests

    record = {
        "arch": arch, "quick": quick, "n_requests": n_requests,
        # true completion count (not config): what run.py --check gates on
        "requests_completed": len(warm_out),
        "system_prompt_tokens": system_len, "suffix_tokens": suffix_len,
        "cold": {"ttft_ms_mean": cold_ttft_ms,
                 "prefill_tokens_executed_per_request": cold_tokens},
        "warm": {"ttft_ms_mean": warm_ttft_ms,
                 "prefill_tokens_executed_per_request": warm_tokens},
        "speedup_ttft": cold_ttft_ms / max(warm_ttft_ms, 1e-9),
        "speedup_prefill_tokens": cold_tokens / max(warm_tokens, 1e-9),
        "tokens_recomputed_per_request_warm": warm_tokens,
        "bit_identical_outputs": True,
        "prefix_cache": pc,
        "cached_prefix_tokens_total": int(warm_eng.cached_prefix_tokens),
    }
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("prefix_cache/cold_ttft", cold_ttft_ms * 1e3,
         f"{cold_tokens:.0f} prefill tokens executed per request"),
        ("prefix_cache/warm_ttft", warm_ttft_ms * 1e3,
         f"{warm_tokens:.0f} prefill tokens executed per request"),
        ("prefix_cache/speedup", 0.0,
         f"ttft x{record['speedup_ttft']:.1f}, prefill FLOPs "
         f"x{record['speedup_prefill_tokens']:.1f}, hit rate "
         f"{pc['hit_rate']:.2f}, results -> {out_path}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_prefix_cache.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
