"""Paper Table 1 / Fig 2: multi-node scaling of the 3DGAN training.

The paper reports near-linear strong scaling of one 3DGAN epoch on 4-32
SuperMUC-NG nodes (3806s -> 504s, 94% efficiency).  This container has ONE
physical core, so wall-clock multi-device timing is meaningless; we
reproduce the claim two ways:

1. **Cost model** (validated against the paper's own numbers): per-epoch
   time = compute/N + ring-allreduce time with the paper's hardware
   (Skylake 48c, OmniPath 100 Gbit/s, 1M-param f32 gradients, steps/epoch
   from the dataset size).  The model must reproduce Table 1 within a few
   percent and predict >=90% efficiency at 32 nodes — the paper's claim.

2. **Collective-bytes measurement**: the hvd-DP train step is compiled for
   1..32 ranks and the per-rank allreduce bytes parsed from the HLO —
   demonstrating the O(2·P) per-rank property that makes (1) hold.
"""
from __future__ import annotations

import re
import subprocess
import sys
from typing import Dict, List, Tuple

import numpy as np

# paper Table 1
PAPER_TABLE1 = {4: 3806.0, 8: 1910.0, 16: 1001.0, 32: 504.0}

# SuperMUC-NG constants
OMNIPATH_BW = 100e9 / 8            # bytes/s
GAN_PARAMS = 1.0e6                 # paper: "slightly less than 1 million"
GRAD_BYTES = GAN_PARAMS * 4


def epoch_time_model(nodes: int, t_compute_4: float,
                     steps_per_epoch: int = 6000,
                     inter_island_penalty: float = 4.0) -> float:
    """t(N) = serial_compute/N + steps * ring_allreduce(N).

    ring allreduce moves 2*(N-1)/N * grad_bytes per rank per step; beyond
    one island (>= 24 nodes here) the pruned 4:1 fat-tree divides effective
    bandwidth (paper §III-A).
    """
    compute = t_compute_4 * 4 / nodes
    bw = OMNIPATH_BW / (inter_island_penalty if nodes > 24 else 1.0)
    allreduce = steps_per_epoch * 2 * (nodes - 1) / nodes * GRAD_BYTES / bw
    # per-step framework overhead (launch, host sync) ~ constant
    overhead = steps_per_epoch * 2e-3
    return compute + allreduce + overhead


def model_vs_paper() -> List[Tuple[str, float, str]]:
    # calibrate single free parameter (compute at 4 nodes) on the first row
    t4 = PAPER_TABLE1[4]
    steps = 6000
    t_compute_4 = t4 - epoch_time_model(4, 0.0, steps)     # residual=comm
    rows = []
    for n, t_paper in PAPER_TABLE1.items():
        t_model = epoch_time_model(n, t_compute_4, steps)
        err = 100 * (t_model - t_paper) / t_paper
        rows.append((f"table1_model/{n}nodes", t_model * 1e6,
                     f"paper={t_paper:.0f}s model={t_model:.0f}s "
                     f"err={err:+.1f}%"))
    t4m = epoch_time_model(4, t_compute_4, steps)
    t32m = epoch_time_model(32, t_compute_4, steps)
    eff = t4m * 4 / (t32m * 32) * 100
    rows.append(("table1_model/scaling_efficiency_32n", 0.0,
                 f"{eff:.1f}% (paper claims ~94%)"))
    return rows


_COLL_RE = re.compile(r"all-reduce")


def measured_allreduce_bytes(ranks: int) -> int:
    """Compile the hvd 3DGAN D-step for ``ranks`` host devices (subprocess)
    and return per-rank all-reduce bytes from the HLO."""
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ranks}"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.models import gan3d as G
from repro.core import hvd
from repro.launch.mesh import make_mesh
from repro import optim
from repro.launch.dryrun import collective_bytes
cfg = G.GAN3DConfig(g_fc_ch=6, g_base=16, d_base=8)
key = jax.random.PRNGKey(0)
mesh = make_mesh(({ranks},), ("data",))
d_opt = optim.rmsprop(1e-3)
def local(dp, ds, gp, batch, z):
    grads, m = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch, z)
    upd, ds = hvd.DistributedOptimizer(d_opt, ("data",)).update(grads, ds, dp)
    return optim.apply_updates(dp, upd), ds
import functools
B = {ranks} * 2
gp_s = jax.eval_shape(lambda k: G.init_generator(k, cfg), key)
dp_s = jax.eval_shape(lambda k: G.init_discriminator(k, cfg), key)
ds_s = jax.eval_shape(d_opt.init, dp_s)
batch_s = {{"images": jax.ShapeDtypeStruct((B,25,25,25,1), jnp.float32),
           "energies": jax.ShapeDtypeStruct((B,), jnp.float32)}}
z_s = jax.ShapeDtypeStruct((B, cfg.latent_dim), jnp.float32)
f = jax.jit(hvd.shard_map(local, mesh=mesh,
    in_specs=(P(), P(), P(), {{"images": P("data"), "energies": P("data")}}, P("data")),
    out_specs=(P(), P()), check_vma=False))
c = f.lower(dp_s, ds_s, gp_s, batch_s, z_s).compile()
cb = collective_bytes(c.as_text())
print("BYTES", sum(cb.values()))
"""
    import os
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-1500:])
    return int([l for l in r.stdout.splitlines()
                if l.startswith("BYTES")][0].split()[1])


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    rows = model_vs_paper()
    sizes = [2, 8] if quick else [2, 4, 8, 16, 32]
    per_rank = {}
    for n in sizes:
        per_rank[n] = measured_allreduce_bytes(n)
        rows.append((f"allreduce_bytes/{n}ranks", 0.0,
                     f"{per_rank[n]:,} B/rank/step"))
    # O(2P) property: per-rank bytes ~ constant in N (ring allreduce)
    vals = list(per_rank.values())
    ratio = max(vals) / max(min(vals), 1)
    rows.append(("allreduce_bytes/flatness", 0.0,
                 f"max/min={ratio:.2f} (ring allreduce: ~2x grad bytes, "
                 f"constant per rank)"))
    return rows


if __name__ == "__main__":
    for r in run(quick=False):
        print(",".join(str(x) for x in r))
