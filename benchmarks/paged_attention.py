"""Paged-attention benchmark: dense vs paged serving at one KV budget.

The claim the paged decode path exists to prove: at the SAME physical KV
memory, block storage + block tables serve strictly more concurrent
sequences than the dense worst-case layout, with greedy outputs
bit-identical.  The dense engine allocates ``max_slots * max_seq_len``
positions up front, so its concurrency is capped by the worst case; the
paged engine spends the identical byte budget on a pool of KV blocks
handed out on demand, so typical (short) sequences pack many more slots
into the same bytes — and when the pool *does* run dry, the scheduler
defers/preempts instead of dropping requests.

Three measurements, written to ``BENCH_paged_attention.json``:

* **dense** — worst-case layout, ``max_slots`` bounded by the budget;
* **paged** — same bytes (``num_blocks + 1`` physical blocks, trash
  block included, equals the dense stripe count), 3x the slots;
* **undersized** — ``num_blocks`` far below worst case with the prefix
  cache on: asserts every request completes (no drops), all prefix pins
  are released at drain, and outputs still match dense bit-for-bit.

  PYTHONPATH=src python -m benchmarks.paged_attention          # smoke
  PYTHONPATH=src python -m benchmarks.paged_attention --full
"""
from __future__ import annotations

import argparse
import time


def _serve(engine, prompts, max_new):
    """Run all prompts through one scheduler; returns (outputs, peak
    concurrent sequences, decode wall seconds, scheduler)."""
    import numpy as np

    from repro.serving import Request, SamplingParams, Scheduler
    sched = Scheduler(engine)
    rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=max_new,
                                                   greedy=True)))
            for p in prompts]
    peak = 0
    t0 = time.perf_counter()
    while sched.has_work:
        sched.step()
        peak = max(peak, len(sched.active))
    wall = time.perf_counter() - t0
    return [sched.output(r) for r in rids], peak, wall, sched


def run(quick: bool = True, out_path: str = "BENCH_paged_attention.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ServingEngine

    arch = "qwen2-0.5b"
    block = 16
    if quick:
        n_requests, max_new = 12, 6
        max_seq_len, dense_slots, paged_slots = 96, 3, 9
        prompt_lens = [4 + (i * 3) % 13 for i in range(n_requests)]
        undersized_blocks = 7
    else:
        n_requests, max_new = 32, 16
        max_seq_len, dense_slots, paged_slots = 256, 4, 16
        prompt_lens = [8 + (i * 7) % 49 for i in range(n_requests)]
        undersized_blocks = 12

    blocks_per_slot = max_seq_len // block
    # identical byte budget: dense stripes == paged blocks incl. trash
    num_blocks = dense_slots * blocks_per_slot - 1

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 3, dtype=np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, n,
                                            dtype=np.int32)])
               for n in prompt_lens]

    def engine(**kw):
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             kv_block_size=block, **kw)

    dense_eng = engine(max_slots=dense_slots)
    dense_out, dense_peak, dense_wall, dsched = _serve(
        dense_eng, prompts, max_new)
    paged_eng = engine(max_slots=paged_slots, paged=True,
                       num_blocks=num_blocks)
    paged_out, paged_peak, paged_wall, psched = _serve(
        paged_eng, prompts, max_new)

    for a, b in zip(dense_out, paged_out):
        np.testing.assert_array_equal(a, b)
    dense_bytes = dense_eng.kv.kv_bytes()
    paged_bytes = paged_eng.kv.kv_bytes()
    assert paged_bytes == dense_bytes, (paged_bytes, dense_bytes)
    assert paged_peak > dense_peak, (
        f"paged served {paged_peak} concurrent vs dense {dense_peak} at "
        f"the same {dense_bytes} KV bytes — paging regressed")

    # -- undersized pool: OutOfBlocks is real; nothing may be dropped ----
    small_eng = engine(max_slots=paged_slots, paged=True,
                       num_blocks=undersized_blocks,
                       prefix_cache_blocks=blocks_per_slot)
    small_out, small_peak, small_wall, ssched = _serve(
        small_eng, prompts, max_new)
    for a, b in zip(dense_out, small_out):
        np.testing.assert_array_equal(a, b)
    assert small_eng.kv.pool.in_use == 0
    small_eng.prefix_cache.evict(10 ** 9)          # leaked pins would stick
    assert small_eng.kv.prefix_pool.in_use == 0, "leaked prefix pins"
    stress = ssched.preemptions + ssched.admission_stalls
    assert stress > 0, "undersized pool never ran dry — not a stress run"

    total_tokens = sum(len(o) for o in dense_out)
    record = {
        "arch": arch, "quick": quick, "n_requests": n_requests,
        "block_size": block, "max_seq_len": max_seq_len,
        "kv_bytes_budget": dense_bytes,
        "dense": {"max_slots": dense_slots,
                  "max_concurrent": dense_peak,
                  "decode_tok_s": total_tokens / dense_wall,
                  "kv_bytes_resident": dense_bytes,
                  "decode_steps": dsched.decode_steps},
        "paged": {"max_slots": paged_slots,
                  "num_blocks": num_blocks,
                  "max_concurrent": paged_peak,
                  "decode_tok_s": total_tokens / paged_wall,
                  "kv_bytes_resident": paged_bytes,
                  "decode_steps": psched.decode_steps,
                  "block_high_water": paged_eng.kv.pool.high_water},
        "undersized": {"num_blocks": undersized_blocks,
                       "worst_case_blocks": paged_slots * blocks_per_slot,
                       "max_concurrent": small_peak,
                       "completed": len(small_out),
                       "dropped": 0,
                       "preemptions": ssched.preemptions,
                       "admission_stalls": ssched.admission_stalls,
                       "leaked_pins": 0,
                       "kv_bytes_resident": small_eng.kv.kv_bytes()},
        "bit_identical_outputs": True,
    }
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("paged_attention/dense", dense_wall * 1e6,
         f"{dense_peak} concurrent max, "
         f"{record['dense']['decode_tok_s']:.1f} tok/s, "
         f"{dense_bytes} KV bytes"),
        ("paged_attention/paged", paged_wall * 1e6,
         f"{paged_peak} concurrent max at the SAME {paged_bytes} KV "
         f"bytes, {record['paged']['decode_tok_s']:.1f} tok/s"),
        ("paged_attention/undersized", small_wall * 1e6,
         f"{undersized_blocks}/{paged_slots * blocks_per_slot} blocks: "
         f"{len(small_out)}/{n_requests} completed, "
         f"{ssched.preemptions} preemptions, "
         f"{ssched.admission_stalls} stalls, bit-identical, "
         f"results -> {out_path}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_paged_attention.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
