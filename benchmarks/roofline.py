"""Roofline analysis (deliverable g): three terms per (arch x shape), from
the compiled dry-run artifacts on the single-pod 16x16 mesh.

  compute term    = HLO_FLOPs_per_device / 197 TFLOP/s   (bf16 MXU peak)
  memory term     = HLO_bytes_per_device / 819 GB/s      (HBM)
  collective term = collective_bytes_per_device / 50 GB/s (ICI link)

FLOPs/bytes come from ``cost_analysis()`` of the UNROLLED G=1/G=2 programs
extrapolated linearly in depth (exact for homogeneous layers — XLA counts a
while-loop body once; see launch/dryrun.py); collective bytes are parsed
from the compiled HLO text.  MODEL_FLOPS = 6·N·D (train) / 2·N_active·D
(inference) catches remat/dispatch overhead in the ratio column.

Writes results/roofline.jsonl and prints the EXPERIMENTS.md table.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_device(arch: str, shape_name: str, chips: int = 256) -> float:
    """Analytic useful-FLOPs per device for the MODEL_FLOPS/HLO_FLOPs ratio."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2 * n_active * shape.global_batch
    return total / chips


def terms(rec: Dict) -> Dict:
    f, b, cb = rec["flops"], rec["bytes_accessed"], rec["collective_bytes_total"]
    t_c = f / PEAK_FLOPS
    t_m = b / HBM_BW
    t_x = cb / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["chips"])
    advice = {
        "compute": "compute-bound: good — push MXU utilization via kernel "
                   "block tuning / fewer rematerialized FLOPs",
        "memory": "HBM-bound: fuse elementwise chains (Pallas rmsnorm), "
                  "reuse KV/cache tiles, bf16-ify residuals",
        "collective": "ICI-bound: reshard (bigger per-shard blocks), "
                      "hierarchical pod-aware allreduce, overlap "
                      "collectives with compute",
    }[dom]
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "strategy": rec.get("strategy"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / f if f else 0.0,
        "advice": advice,
        "collective_breakdown": rec.get("collective_bytes", {}),
    }


def fmt_row(t: Dict) -> str:
    return (f"| {t['arch']} | {t['shape']} | {t['strategy']} "
            f"| {t['compute_s']*1e3:9.3f} | {t['memory_s']*1e3:9.3f} "
            f"| {t['collective_s']*1e3:9.3f} | {t['dominant']:10s} "
            f"| {t['useful_flops_ratio']:5.2f} |")


def run_sweep(out_path: str, pairs: Optional[List] = None) -> List[Dict]:
    """Run roofline_pair for every (arch, shape) in a 512-device subprocess
    (one process for the whole sweep)."""
    prog = """
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import roofline_pair
from repro.configs import ARCHS
from repro.configs.base import SHAPES
pairs = json.loads(sys.argv[1]) if len(sys.argv) > 1 else \
    [(a, s) for a in ARCHS for s in SHAPES]
for a, s in pairs:
    try:
        rec = roofline_pair(a, s)
    except Exception as e:
        import traceback; traceback.print_exc()
        rec = {"arch": a, "shape": s, "status": "fail",
               "error": f"{type(e).__name__}: {e}"}
    print("REC " + json.dumps(rec), flush=True)
"""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    args = [sys.executable, "-c", prog]
    if pairs:
        args.append(json.dumps(pairs))
    r = subprocess.run(args, capture_output=True, text=True, env=env)
    recs = [json.loads(l[4:]) for l in r.stdout.splitlines()
            if l.startswith("REC ")]
    if out_path:
        with open(out_path, "w") as f:
            for rec in recs:
                f.write(json.dumps(rec) + "\n")
    if r.returncode != 0 and not recs:
        raise RuntimeError(r.stderr[-2000:])
    return recs


def table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | strategy | compute ms | memory ms | "
             "collective ms | dominant | useful-FLOPs ratio |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        if rec.get("status") == "ok":
            lines.append(fmt_row(terms(rec)))
        elif rec.get("status") == "skip":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — "
                         f"| skip | — |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/roofline.jsonl")
    ap.add_argument("--pairs", help="JSON list of [arch, shape] pairs")
    ap.add_argument("--from-file", help="render table from existing jsonl")
    args = ap.parse_args()
    if args.from_file:
        recs = [json.loads(l) for l in open(args.from_file)]
    else:
        pairs = json.loads(args.pairs) if args.pairs else None
        recs = run_sweep(args.out, pairs)
    print(table(recs))


if __name__ == "__main__":
    main()
