"""Benchmark harness — one entry per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (quick mode by default; pass
--full for the long versions).

  Table 1 / Fig 2  -> scaling            (cost model vs paper + HLO bytes)
  Table 2 / 3      -> container_overhead (capsule vs bare throughput/memory)
  SII-H            -> allreduce_vs_ps    (collective-traffic contrast)
  deliverable (g)  -> roofline           (summary of results/roofline.jsonl)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _roofline_summary(rows):
    path = "results/roofline.jsonl"
    if not os.path.exists(path):
        rows.append(("roofline/missing", 0.0,
                     "run: python -m benchmarks.roofline"))
        return
    from benchmarks.roofline import terms
    recs = [json.loads(l) for l in open(path)]
    ok = [r for r in recs if r.get("status") == "ok"]
    doms = {}
    for rec in ok:
        t = terms(rec)
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
        rows.append((f"roofline/{rec['arch']}/{rec['shape']}",
                     (t["compute_s"] + t["memory_s"] + t["collective_s"]) * 1e6,
                     f"dom={t['dominant']} c={t['compute_s']*1e3:.2f}ms "
                     f"m={t['memory_s']*1e3:.2f}ms "
                     f"x={t['collective_s']*1e3:.2f}ms "
                     f"useful={t['useful_flops_ratio']:.2f}"))
    rows.append(("roofline/dominant_terms", 0.0,
                 " ".join(f"{k}:{v}" for k, v in sorted(doms.items()))))


def _kernel_micro(rows):
    """Microbenchmark the jnp hot paths the Pallas kernels replace (CPU
    timings; the kernels themselves are TPU-target, validated in
    interpret mode by tests/test_kernels.py)."""
    import jax
    import jax.numpy as jnp
    from repro.models.attention import attend
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)

    q = jax.random.normal(key, (1, 512, 2, 4, 64), jnp.bfloat16)
    k = jax.random.normal(key, (1, 512, 2, 64), jnp.bfloat16)
    v = jax.random.normal(key, (1, 512, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: attend(q, k, v, scale=0.125, causal=True))
    f(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        out = f(q, k, v)
    out.block_until_ready()
    rows.append(("attend_ref/512tok_bf16", (time.perf_counter() - t0) / 10 * 1e6,
                 "jnp reference path (Pallas flash kernel = TPU hot path)"))

    x = jax.random.normal(key, (1, 512, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)))
    B = jax.random.normal(key, (1, 512, 1, 64))
    C = jax.random.normal(key, (1, 512, 1, 64))
    g = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    g(x, dt, A, B, C).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        out = g(x, dt, A, B, C)
    out.block_until_ready()
    rows.append(("ssd_ref/512tok", (time.perf_counter() - t0) / 10 * 1e6,
                 "jnp reference path (Pallas ssd_scan = TPU hot path)"))


def _check_bench_json() -> list:
    """CI guard: every emitted BENCH_*.json must carry a nonzero
    completed-request count, and ``bit_identical_outputs`` — where the
    benchmark records one — must be true.  A benchmark that silently
    stopped completing work or lost bit-identity fails the build instead
    of shipping a green-looking artifact."""
    import glob

    def dicts(o):
        if isinstance(o, dict):
            yield o
            for v in o.values():
                yield from dicts(v)
        elif isinstance(o, list):
            for v in o:
                yield from dicts(v)

    errors = []
    paths = sorted(glob.glob("BENCH_*.json"))
    if not paths:
        return ["--check: no BENCH_*.json artifacts found"]
    for p in paths:
        try:
            with open(p) as f:
                data = json.load(f)
        except Exception as e:                       # noqa: BLE001
            errors.append(f"{p}: unreadable ({e})")
            continue
        bits = [d["bit_identical_outputs"] for d in dicts(data)
                if "bit_identical_outputs" in d]
        if any(v is not True for v in bits):
            errors.append(f"{p}: bit_identical_outputs is not true")
        # true completion counters only — n_requests is configuration
        # (always nonzero by construction) and would make this vacuous
        counts = [d[k] for d in dicts(data)
                  for k in ("requests_completed", "completed")
                  if isinstance(d.get(k), (int, float))]
        if not counts:
            errors.append(f"{p}: no completed-request count found")
        elif max(counts) <= 0:
            errors.append(f"{p}: zero completed requests")
        if p in ("BENCH_tracing.json", "BENCH_slo.json"):
            errors.extend(_check_overhead_bound(p, data, dicts))
        if p in ("BENCH_faults.json", "BENCH_fabric.json"):
            errors.extend(_check_faults(p, data))
    return errors


def _check_faults(p: str, data) -> list:
    """The fault-tolerance and fabric artifacts must prove the failover
    claim: the kill salvaged work (not a no-op crash), every salvaged
    request completed on a survivor, and nothing resolved to a typed
    failure."""
    errors = []
    for k in ("salvage_success_rate", "salvaged_requests",
              "failed_requests", "failovers"):
        if not isinstance(data.get(k), (int, float)):
            errors.append(f"{p}: missing or non-numeric '{k}'")
    if errors:
        return errors
    if data["salvaged_requests"] <= 0 or data["failovers"] <= 0:
        errors.append(f"{p}: the injected kill salvaged nothing — the "
                      f"crash landed after the burst finished")
    if data["salvage_success_rate"] != 1.0:
        errors.append(f"{p}: salvage_success_rate "
                      f"{data['salvage_success_rate']} != 1.0 — salvaged "
                      f"requests were lost")
    if data["failed_requests"] != 0:
        errors.append(f"{p}: {data['failed_requests']} request(s) "
                      f"resolved to typed failures with survivors "
                      f"available")
    return errors


def _check_overhead_bound(p: str, data, dicts) -> list:
    """The tracing/observatory artifacts must *prove* their overhead
    claim: enabled-vs-disabled walls, their ratio, and a bound no looser
    than the documented 5% must all be present, with ratio <= bound.  A
    benchmark that quietly stopped measuring the disabled baseline (or
    relaxed its own budget) fails the build here, not in a review."""
    fields = ("disabled_wall_s", "enabled_wall_s", "overhead_ratio",
              "overhead_bound")
    holders = [d for d in dicts(data)
               if all(isinstance(d.get(k), (int, float)) for k in fields)]
    if not holders:
        missing = sorted({k for k in fields
                          if not any(isinstance(d.get(k), (int, float))
                                     for d in dicts(data))})
        return [f"{p}: overhead-bound fields missing or non-numeric "
                f"({', '.join(missing) or 'scattered across dicts'})"]
    errors = []
    for d in holders:
        if d["overhead_bound"] > 1.05:
            errors.append(f"{p}: overhead_bound {d['overhead_bound']} is "
                          f"looser than the documented 5% budget (1.05)")
        if d["overhead_ratio"] > d["overhead_bound"]:
            errors.append(f"{p}: overhead_ratio {d['overhead_ratio']:.4f} "
                          f"exceeds its bound {d['overhead_bound']}")
        if min(d["disabled_wall_s"], d["enabled_wall_s"]) <= 0:
            errors.append(f"{p}: non-positive wall-clock measurement")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", help="comma list: scaling,overhead,ps,physics,"
                                   "roofline,kernels,serving,prefix_cache,"
                                   "paged_attention,batched_prefill,"
                                   "interleaved,tracing,slo,"
                                   "fault_tolerance,fabric")
    ap.add_argument("--check", action="store_true",
                    help="after running, validate every BENCH_*.json in "
                         "the cwd (bit_identical_outputs true where "
                         "present, nonzero completed requests, and the "
                         "tracing/slo overhead ratio present and within "
                         "its documented 5%% bound) and exit nonzero on "
                         "any failure")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def want(name):
        return only is None or name in only

    if want("scaling"):
        from benchmarks import scaling
        try:
            rows += scaling.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("scaling/FAILED", 0.0, "see stderr"))
    if want("ps"):
        from benchmarks import allreduce_vs_ps
        try:
            rows += allreduce_vs_ps.run()
        except Exception:
            traceback.print_exc()
            rows.append(("allreduce_vs_ps/FAILED", 0.0, "see stderr"))
    if want("overhead"):
        from benchmarks import container_overhead
        try:
            rows += container_overhead.run()
        except Exception:
            traceback.print_exc()
            rows.append(("container_overhead/FAILED", 0.0, "see stderr"))
    if want("kernels"):
        try:
            _kernel_micro(rows)
        except Exception:
            traceback.print_exc()
            rows.append(("kernels/FAILED", 0.0, "see stderr"))
    if want("serving"):
        from benchmarks import serving_throughput
        try:
            rows += serving_throughput.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("serving/FAILED", 0.0, "see stderr"))
    if want("prefix_cache"):
        from benchmarks import prefix_cache
        try:
            rows += prefix_cache.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("prefix_cache/FAILED", 0.0, "see stderr"))
    if want("paged_attention"):
        from benchmarks import paged_attention
        try:
            rows += paged_attention.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("paged_attention/FAILED", 0.0, "see stderr"))
    if want("batched_prefill"):
        from benchmarks import batched_prefill
        try:
            rows += batched_prefill.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("batched_prefill/FAILED", 0.0, "see stderr"))
    if want("interleaved"):
        from benchmarks import interleaved_prefill
        try:
            rows += interleaved_prefill.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("interleaved_prefill/FAILED", 0.0, "see stderr"))
    if want("tracing"):
        from benchmarks import tracing_overhead
        try:
            rows += tracing_overhead.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("tracing_overhead/FAILED", 0.0, "see stderr"))
    if want("slo"):
        from benchmarks import slo_observatory
        try:
            rows += slo_observatory.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("slo_observatory/FAILED", 0.0, "see stderr"))
    if want("fault_tolerance"):
        from benchmarks import fault_tolerance
        try:
            rows += fault_tolerance.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("fault_tolerance/FAILED", 0.0, "see stderr"))
    if want("fabric"):
        from benchmarks import fabric
        try:
            rows += fabric.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            rows.append(("fabric/FAILED", 0.0, "see stderr"))
    if want("physics"):
        from benchmarks import physics_validation
        try:
            rows += physics_validation.run(
                train_steps=60 if args.full else 25)
        except Exception:
            traceback.print_exc()
            rows.append(("physics/FAILED", 0.0, "see stderr"))
    if want("roofline"):
        _roofline_summary(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.check:
        errors = [f"{name}: benchmark failed" for name, _, _ in rows
                  if name.endswith("/FAILED")]
        errors += _check_bench_json()
        if errors:
            for e in errors:
                print(f"CHECK FAILED: {e}", file=sys.stderr)
            sys.exit(1)
        print("check: all BENCH_*.json artifacts healthy")


if __name__ == "__main__":
    main()
