"""Tracing overhead benchmark: enabled vs disabled on the interleaved
workload, plus a sample trace artifact check.

Tracing is only admissible in the serving hot loop if it is effectively
free: the claim is that an *enabled* tracer (dict events appended to a
bounded ring) keeps wall-clock throughput within 5% of a *disabled* one
(pure metrics forwarding) on the interleaved prefill/decode workload —
and that turning it on does not perturb the computation (greedy outputs
bit-identical traced vs untraced).

Two measurements, written to ``BENCH_tracing.json``:

* **overhead** — the interleaved-benchmark request stream (2 long
  decodes + 3x8-deep prompt bursts, paged engine, budgeted prefill) run
  with tracing off and on in alternating order (A/B then B/A, cancelling
  thermal/dispatch drift), medians over reps; asserts
  ``enabled_wall <= 1.05 x disabled_wall`` and bit-identical outputs;
* **sample trace** — an 8-request traced run exported to
  ``results/trace_sample.jsonl`` + ``results/trace_sample.chrome.json``;
  asserts the Chrome file loads as valid JSON with >= 1 async span per
  request covering submit -> retire, and every JSONL event passes the
  documented schema (``scripts/trace_report.py --validate`` re-checks
  the same file in CI).

  PYTHONPATH=src python -m benchmarks.tracing_overhead          # smoke
  PYTHONPATH=src python -m benchmarks.tracing_overhead --full
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.interleaved_prefill import (BURST_DEPTH, BURST_STEPS,
                                            MAX_NEW_BURST, MAX_NEW_LONG,
                                            N_LONG, _warmup, _workload)

SAMPLE_REQUESTS = 8
OVERHEAD_BOUND = 1.05


def _serve(engine, cfg, budget, tracer):
    """One interleaved-workload run through a scheduler wearing
    ``tracer`` (enabled or disabled — same code path either way)."""
    from repro.serving import Request, SamplingParams, Scheduler
    longs, bursts = _workload(cfg)
    sched = Scheduler(engine, prefill_token_budget=budget, tracer=tracer)
    rids = [sched.submit(Request(p, SamplingParams(
        max_new_tokens=MAX_NEW_LONG, greedy=True))) for p in longs]
    pending = list(zip(BURST_STEPS, bursts))
    steps = 0
    t0 = time.perf_counter()
    while sched.has_work or pending:
        if pending and steps >= pending[0][0]:
            burst = pending.pop(0)[1]
            rids += [sched.submit(Request(p, SamplingParams(
                max_new_tokens=MAX_NEW_BURST, greedy=True)))
                for p in burst]
        sched.step()
        steps += 1
    wall = time.perf_counter() - t0
    return [sched.output(r) for r in rids], sched.metrics.summary(), wall


def _sample_trace(engine, cfg, budget, jsonl_path, chrome_path):
    """Traced 8-request run; export + verify both artifacts."""
    import numpy as np
    from repro.serving import (Request, SamplingParams, Scheduler, Tracer,
                               export_chrome_trace, validate_event)

    tracer = Tracer(enabled=True, name="replica0")
    sched = Scheduler(engine, prefill_token_budget=budget, tracer=tracer)
    rng = np.random.default_rng(7)
    rids = [sched.submit(Request(
        rng.integers(0, cfg.vocab_size, int(rng.integers(8, 32)),
                     dtype=np.int32),
        SamplingParams(max_new_tokens=4, greedy=True)))
        for _ in range(SAMPLE_REQUESTS)]
    sched.run()

    jsonl = tracer.export_jsonl(jsonl_path)
    chrome = export_chrome_trace({tracer.name: tracer.snapshot()},
                                 chrome_path)

    # every exported line obeys the documented schema
    events = [json.loads(l) for l in jsonl.read_text().splitlines() if l]
    for ev in events:
        err = validate_event(ev)
        assert err is None, f"schema violation in {jsonl}: {err}: {ev}"
    # every request's span covers submit -> retire in the event log ...
    for rid in rids:
        kinds = {ev["kind"] for ev in events if ev.get("rid") == rid}
        assert {"submit", "retire"} <= kinds, (
            f"req {rid} span incomplete: has {sorted(kinds)}")
    # ... and the Chrome file is valid JSON with one async lane per
    # request, opened (b) and closed (e)
    doc = json.loads(chrome.read_text())
    tevs = doc["traceEvents"]
    for rid in rids:
        span = f"{tracer.name}/req{rid}"
        phs = {e["ph"] for e in tevs if e.get("id") == span}
        assert {"b", "e"} <= phs, f"span {span} not closed: {phs}"
    return {
        "requests": SAMPLE_REQUESTS,
        "events": len(events),
        "dropped_events": tracer.dropped_events,
        "spans": SAMPLE_REQUESTS,
        "jsonl": str(jsonl),
        "chrome": str(chrome),
    }


def run(quick: bool = True, out_path: str = "BENCH_tracing.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ServingEngine, Tracer

    arch = "qwen2-0.5b"
    block, max_seq_len, slots, prefill_batch, chunk = 16, 64, 12, 4, 8
    budget = prefill_batch * chunk
    reps = 3 if quick else 5

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    num_blocks = slots * (max_seq_len // block)

    def engine():
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=slots, kv_block_size=block,
                             prefill_chunk=chunk,
                             prefill_batch=prefill_batch,
                             paged=True, num_blocks=num_blocks)

    # one engine serves both modes: identical compile caches, identical
    # allocator state pattern — the only variable is the tracer flag
    eng = engine()
    _warmup(eng, cfg)
    _serve(eng, cfg, budget, Tracer())               # warm discarded rep

    off_walls, on_walls = [], []
    off_out = on_out = None
    on_sum = {}
    events_recorded = 0
    for rep in range(reps):
        order = ("off", "on") if rep % 2 == 0 else ("on", "off")
        for mode in order:
            if mode == "off":
                off_out, _off_sum, wall = _serve(eng, cfg, budget, Tracer())
                off_walls.append(wall)
            else:
                tr = Tracer(enabled=True)
                on_out, on_sum, wall = _serve(eng, cfg, budget, tr)
                on_walls.append(wall)
                events_recorded = tr.emitted_events

    for a, b in zip(off_out, on_out):
        np.testing.assert_array_equal(a, b)          # tracing is inert

    n_req = N_LONG + BURST_DEPTH * len(BURST_STEPS)
    assert on_sum["requests_completed"] == n_req
    off_wall = sorted(off_walls)[reps // 2]
    on_wall = sorted(on_walls)[reps // 2]
    ratio = on_wall / off_wall
    assert ratio <= OVERHEAD_BOUND, (
        f"enabled tracing cost {(ratio - 1) * 100:.1f}% wall clock "
        f"({on_wall:.3f}s vs {off_wall:.3f}s disabled, medians of "
        f"{reps}) — over the {(OVERHEAD_BOUND - 1) * 100:.0f}% budget")

    sample = _sample_trace(engine(), cfg, budget,
                           "results/trace_sample.jsonl",
                           "results/trace_sample.chrome.json")

    record = {
        "arch": arch, "quick": quick, "n_requests": n_req, "reps": reps,
        "block_size": block, "max_seq_len": max_seq_len,
        "max_slots": slots, "num_blocks": num_blocks,
        "prefill_token_budget": budget,
        "disabled_wall_s": off_wall,
        "enabled_wall_s": on_wall,
        "overhead_ratio": ratio,
        "overhead_bound": OVERHEAD_BOUND,
        "events_per_run": events_recorded,
        "requests_completed": on_sum["requests_completed"],
        "bit_identical_outputs": True,
        "sample_trace": sample,
    }
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("tracing_overhead/disabled", off_wall * 1e6,
         f"interleaved workload, tracer off (metrics-only path), "
         f"median of {reps}"),
        ("tracing_overhead/enabled", on_wall * 1e6,
         f"tracer on: {events_recorded} events/run, "
         f"{(ratio - 1) * 100:+.1f}% wall vs disabled "
         f"(bound {(OVERHEAD_BOUND - 1) * 100:.0f}%), bit-identical, "
         f"results -> {out_path}"),
        ("tracing_overhead/sample_trace", 0.0,
         f"{sample['requests']} requests -> {sample['events']} events, "
         f"all spans submit->retire, {sample['jsonl']} + "
         f"{sample['chrome']} valid"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_tracing.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
