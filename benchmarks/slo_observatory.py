"""Serving-observatory overhead benchmark: the full telemetry stack
(per-tenant SLO monitoring + device-accurate step profiling + recompile
tracking) enabled vs disabled on the interleaved workload.

The observatory is only admissible in the serving hot loop if it is
effectively free and inert: the claim is that an armed observatory —
enabled tracer, per-tenant sliding-window percentiles, an SLO monitor
evaluated every step, ``block_until_ready``-bracketed phase timing and
shape-signature recompile tracking — keeps wall clock within 5% of the
bare metrics path on the interleaved prefill/decode workload, with
greedy outputs bit-identical.

Four measurements, written to ``BENCH_slo.json``:

* **overhead** — the interleaved-benchmark request stream (2 long
  decodes + 3x8-deep prompt bursts, paged engine, budgeted prefill),
  requests labelled round-robin across two tenants, run with the
  observatory off and on in alternating order (A/B then B/A), medians
  over reps; asserts ``on_wall <= 1.05 x off_wall`` and bit-identical
  outputs;
* **recompiles** — after warmup the tracker is marked warm; asserts
  steady-state interleaved serving causes *zero* post-warm
  recompilations across every measured rep (both modes share one
  engine, so a drifting shape in either would trip it);
* **fleet rollup** — a 2-replica gateway run with tenant labels;
  asserts the merged multi-replica summary carries per-tenant TTFT
  p95 > 0 and inter-token-gap percentiles for both tenants;
* **breach demo** — one run under a deliberately impossible policy
  (TTFT p95 <= 0.001 ms); asserts the monitor records breaches and at
  least one ``slo_breach`` event lands in the trace buffer.

A paged-kernel cost/roofline profile (``profile_paged_kernels``) is
recorded alongside for the report, not asserted on: CPU wall numbers
for TPU-target kernels are context, not claims.

  PYTHONPATH=src python -m benchmarks.slo_observatory          # smoke
  PYTHONPATH=src python -m benchmarks.slo_observatory --full
"""
from __future__ import annotations

import argparse
import time

from benchmarks.interleaved_prefill import (BURST_DEPTH, BURST_STEPS,
                                            MAX_NEW_BURST, MAX_NEW_LONG,
                                            N_LONG, _warmup, _workload)

TENANTS = ("tenant-a", "tenant-b")
OVERHEAD_BOUND = 1.05
FLEET_REQUESTS = 10


def _serve(engine, cfg, budget, tracer, profile):
    """One interleaved-workload run, requests labelled round-robin over
    ``TENANTS``, through a scheduler wearing ``tracer`` (armed or not)
    and optionally the step profiler — same code path either way."""
    from repro.serving import Request, SamplingParams, Scheduler
    longs, bursts = _workload(cfg)
    sched = Scheduler(engine, prefill_token_budget=budget, tracer=tracer,
                      profile=profile)
    n_sub = 0

    def sub(prompt, max_new):
        nonlocal n_sub
        rid = sched.submit(Request(
            prompt, SamplingParams(max_new_tokens=max_new, greedy=True),
            tenant=TENANTS[n_sub % len(TENANTS)]))
        n_sub += 1
        return rid

    rids = [sub(p, MAX_NEW_LONG) for p in longs]
    pending = list(zip(BURST_STEPS, bursts))
    steps = 0
    t0 = time.perf_counter()
    while sched.has_work or pending:
        if pending and steps >= pending[0][0]:
            burst = pending.pop(0)[1]
            rids += [sub(p, MAX_NEW_BURST) for p in burst]
        sched.step()
        steps += 1
    wall = time.perf_counter() - t0
    return [sched.output(r) for r in rids], sched.metrics.summary(), wall


def _fleet_rollup(engine_fn, cfg, budget, slo_config):
    """2-replica gateway with tenant labels: the merged summary must
    carry per-tenant percentiles, not just per-replica ones."""
    import numpy as np
    from repro.serving import ReplicaGateway, Request, SamplingParams

    gw = ReplicaGateway.from_engines(
        [engine_fn(), engine_fn()], prefill_token_budget=budget,
        tracing=True, slo_config=slo_config, profile=True)
    rng = np.random.default_rng(5)
    for i in range(FLEET_REQUESTS):
        gw.submit(Request(
            rng.integers(0, cfg.vocab_size, int(rng.integers(8, 24)),
                         dtype=np.int32),
            SamplingParams(max_new_tokens=6, greedy=True),
            tenant=TENANTS[i % len(TENANTS)]))
    gw.drain()
    totals = gw.stats()["totals"]
    assert totals["requests_completed"] == FLEET_REQUESTS
    for t in TENANTS:
        ts = totals["tenants"][t]
        assert ts["requests_completed"] > 0, f"{t}: no completions merged"
        assert ts["ttft_ms"]["p95"] > 0, f"{t}: TTFT p95 missing"
        assert {"p50", "p95", "max"} <= set(ts["decode_gap_ms"]), (
            f"{t}: gap percentiles missing from merged rollup")
    return {
        "replicas": 2, "requests": FLEET_REQUESTS,
        "tenants": {t: {"requests_completed":
                        totals["tenants"][t]["requests_completed"],
                        "ttft_p95_ms": totals["tenants"][t]["ttft_ms"]["p95"],
                        "gap_p95_ms":
                        totals["tenants"][t]["decode_gap_ms"]["p95"]}
                    for t in TENANTS},
        "slo_breaches": totals.get("slo_breaches", 0),
    }


def _breach_demo(engine, cfg, budget):
    """An impossible policy must breach, and the breach must land in
    the trace buffer as an ``slo_breach`` event."""
    from repro.serving import SLOConfig, SLOMonitor, Tracer
    tight = SLOConfig.from_dict({
        "default": {"ttft_p95_ms": 0.001, "min_samples": 1}})
    tracer = Tracer(enabled=True, slo=SLOMonitor(tight))
    _serve(engine, cfg, budget, tracer, False)
    breaches = tracer.slo.breaches
    events = [e for e in tracer.snapshot() if e["kind"] == "slo_breach"]
    assert breaches >= 1, "impossible TTFT policy did not breach"
    assert events, "breach not emitted as an slo_breach trace event"
    return {"breaches": breaches, "breach_events": len(events)}


def run(quick: bool = True, out_path: str = "BENCH_slo.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import (ServingEngine, SLOConfig, SLOMonitor, Tracer,
                               profile_paged_kernels)

    arch = "qwen2-0.5b"
    block, max_seq_len, slots, prefill_batch, chunk = 16, 64, 12, 4, 8
    budget = prefill_batch * chunk
    reps = 3 if quick else 5

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    num_blocks = slots * (max_seq_len // block)

    def engine():
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=slots, kv_block_size=block,
                             prefill_chunk=chunk,
                             prefill_batch=prefill_batch,
                             paged=True, num_blocks=num_blocks)

    # generous policy: the cost of *evaluating* SLOs every step is what
    # is being measured, not the cost of breaching them
    slo_config = SLOConfig.from_dict({
        "default": {"ttft_p95_ms": 60_000.0, "gap_p95_ms": 60_000.0,
                    "queue_wait_p95_ms": 60_000.0}})

    def armed_tracer():
        return Tracer(enabled=True, slo=SLOMonitor(slo_config))

    # one engine serves both modes: identical compile caches — the only
    # variable is the observatory; the warm rep covers every shape the
    # workload compiles, so post-warm novelty below is a regression
    eng = engine()
    _warmup(eng, cfg)
    _serve(eng, cfg, budget, armed_tracer(), True)   # warm discarded rep
    eng.recompiles.mark_warm()

    off_walls, on_walls = [], []
    off_out = on_out = None
    on_sum = {}
    events_recorded = 0
    for rep in range(reps):
        order = ("off", "on") if rep % 2 == 0 else ("on", "off")
        for mode in order:
            if mode == "off":
                off_out, _off_sum, wall = _serve(eng, cfg, budget,
                                                 Tracer(), False)
                off_walls.append(wall)
            else:
                tr = armed_tracer()
                on_out, on_sum, wall = _serve(eng, cfg, budget, tr, True)
                on_walls.append(wall)
                events_recorded = tr.emitted_events

    for a, b in zip(off_out, on_out):
        np.testing.assert_array_equal(a, b)          # observatory is inert

    n_req = N_LONG + BURST_DEPTH * len(BURST_STEPS)
    assert on_sum["requests_completed"] == n_req
    for t in TENANTS:
        assert on_sum["tenants"][t]["ttft_ms"]["count"] > 0

    recomp = eng.recompiles.summary()
    assert recomp["post_warm_recompiles"] == 0, (
        f"steady-state serving recompiled post-warm: {recomp}")

    off_wall = sorted(off_walls)[reps // 2]
    on_wall = sorted(on_walls)[reps // 2]
    ratio = on_wall / off_wall
    assert ratio <= OVERHEAD_BOUND, (
        f"armed observatory cost {(ratio - 1) * 100:.1f}% wall clock "
        f"({on_wall:.3f}s vs {off_wall:.3f}s bare, medians of {reps}) — "
        f"over the {(OVERHEAD_BOUND - 1) * 100:.0f}% budget")

    kernels = {name: {k: prof[k] for k in
                      ("wall_ms_median", "flops", "bytes_accessed",
                       "achieved_tflops", "fraction_of_peak_flops",
                       "achieved_gbps", "fraction_of_peak_bw",
                       "arithmetic_intensity")}
               for name, prof in profile_paged_kernels(eng).items()}

    fleet = _fleet_rollup(engine, cfg, budget, slo_config)
    breach = _breach_demo(engine(), cfg, budget)

    record = {
        "arch": arch, "quick": quick, "n_requests": n_req, "reps": reps,
        "block_size": block, "max_seq_len": max_seq_len,
        "max_slots": slots, "num_blocks": num_blocks,
        "prefill_token_budget": budget,
        "tenants": list(TENANTS),
        "disabled_wall_s": off_wall,
        "enabled_wall_s": on_wall,
        "overhead_ratio": ratio,
        "overhead_bound": OVERHEAD_BOUND,
        "events_per_run": events_recorded,
        "requests_completed": on_sum["requests_completed"],
        "bit_identical_outputs": True,
        "per_tenant": on_sum["tenants"],
        "recompiles": recomp,
        "kernel_profiles": kernels,
        "fleet_rollup": fleet,
        "breach_demo": breach,
    }
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    ta = on_sum["tenants"][TENANTS[0]]
    rows = [
        ("slo_observatory/disabled", off_wall * 1e6,
         f"interleaved workload, bare metrics path, median of {reps}"),
        ("slo_observatory/enabled", on_wall * 1e6,
         f"SLO monitor + step profiler + recompile tracker on: "
         f"{(ratio - 1) * 100:+.1f}% wall vs bare "
         f"(bound {(OVERHEAD_BOUND - 1) * 100:.0f}%), bit-identical, "
         f"{recomp['post_warm_recompiles']} post-warm recompiles, "
         f"results -> {out_path}"),
        ("slo_observatory/per_tenant", 0.0,
         f"{TENANTS[0]}: ttft p95 {ta['ttft_ms']['p95']:.1f} ms, "
         f"gap p95 {ta['decode_gap_ms']['p95']:.2f} ms over "
         f"{ta['requests_completed']} requests; fleet rollup over "
         f"{fleet['replicas']} replicas carries both tenants"),
        ("slo_observatory/breach_demo", 0.0,
         f"impossible policy: {breach['breaches']} breach(es), "
         f"{breach['breach_events']} slo_breach event(s) in trace"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
