"""Paper Tables 2 & 3: throughput and memory with/without the container.

The paper measured AlexNet/ResNet-50 img/s and free system memory with and
without Charliecloud and found no measurable overhead.  We measure the same
thing for our capsule runtime: an identical jitted 3DGAN discriminator
training step executed (a) bare and (b) inside ``CapsuleRuntime.run`` with
env scrubbing + image-hash verification amortized across the run.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import deploy as D
from repro.data import CalorimeterSpec, generate_batch
from repro.models import gan3d as G


def _rss_mb() -> float:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS"):
                return int(line.split()[1]) / 1024.0
    return float("nan")


def _make_step(cfg):
    d_opt = optim.rmsprop(1e-3)

    @jax.jit
    def step(dp, ds, gp, batch, z):
        grads, m = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch, z)
        upd, ds = d_opt.update(grads, ds, dp)
        return optim.apply_updates(dp, upd), ds, m

    return step, d_opt


def _train(steps: int, batch_size: int):
    cfg = G.GAN3DConfig(g_fc_ch=6, g_base=16, d_base=8)
    key = jax.random.PRNGKey(0)
    gp = G.init_generator(key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(key, 1), cfg)
    step, d_opt = _make_step(cfg)
    ds = d_opt.init(dp)
    batch = {k: jnp.asarray(v)
             for k, v in generate_batch(CalorimeterSpec(), batch_size).items()}
    z = jax.random.normal(key, (batch_size, cfg.latent_dim))
    dp, ds, _ = step(dp, ds, gp, batch, z)      # compile
    jax.block_until_ready(jax.tree.leaves(dp)[0])
    t0 = time.perf_counter()
    for _ in range(steps):
        dp, ds, _ = step(dp, ds, gp, batch, z)
    jax.block_until_ready(jax.tree.leaves(dp)[0])
    dt = time.perf_counter() - t0
    return {"img_per_s": steps * batch_size / dt,
            "s_per_step": dt / steps, "rss_mb": _rss_mb()}


def run(steps: int = 8, batch_size: int = 8, rounds: int = 2):
    """Interleave bare/capsule rounds and take per-mode minima (the paper's
    Table 2 methodology measures steady-state throughput; interleaving
    cancels order/warm-cache effects on a shared-core container)."""
    with tempfile.TemporaryDirectory() as td:
        pipe = D.DeploymentPipeline()
        dep = pipe.deploy(D.intel_tensorflow_image("bench"), Path(td))
        bares, conts = [], []
        for _ in range(rounds):
            bares.append(_train(steps, batch_size))
            conts.append(dep.run(_train, steps, batch_size)[0].value)
    bare = min(bares, key=lambda r: r["s_per_step"])
    contained = min(conts, key=lambda r: r["s_per_step"])
    rows = [
        ("3dgan_d_step/with_capsule", contained["s_per_step"] * 1e6,
         f"img_per_s={contained['img_per_s']:.2f}"),
        ("3dgan_d_step/bare", bare["s_per_step"] * 1e6,
         f"img_per_s={bare['img_per_s']:.2f}"),
        ("capsule_overhead_pct",
         abs(contained["s_per_step"] - bare["s_per_step"]) * 1e6,
         f"{100*(contained['s_per_step']/bare['s_per_step']-1):+.2f}%"),
        ("rss_delta_mb", 0.0,
         f"{contained['rss_mb'] - bare['rss_mb']:+.1f}MB"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
