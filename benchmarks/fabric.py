"""Fabric benchmark: 3 *subprocess* replicas, SIGKILL one mid-burst.

The cross-process version of the PR 9 fleet claim, measured end to end
over the real transport: three replica workers launched as separate
processes by :class:`~repro.serving.fabric.backends.LocalProcessBackend`,
talking to the gateway only through the shared-filesystem mailbox, serve
a greedy burst; one worker is SIGKILLed while its heartbeat shows
in-flight requests.  The gateway observes the death exactly as a real
cluster would (the process vanishes, heartbeats stop), salvages the
victim's queued + in-flight work from its last heartbeat's emitted-token
map, re-routes to the survivors — and **every** request still completes
bit-identical to a fault-free single-process oracle run.

Written to ``BENCH_fabric.json`` (validated by ``benchmarks/run.py
--check`` with the same schema as the fault-tolerance artifact):

* ``requests_completed == n_requests`` and ``failed_requests == 0``;
* ``salvage_success_rate == 1.0`` — every salvaged request completed on
  a surviving process;
* ``bit_identical_outputs`` — fleet-under-kill outputs equal the
  oracle's, token for token, across the process boundary;
* the merged gateway + worker trace timeline is exported to
  ``results/trace_fabric.jsonl`` for ``scripts/trace_report.py
  --fleet``.

  PYTHONPATH=src python -m benchmarks.fabric          # smoke
  PYTHONPATH=src python -m benchmarks.fabric --full
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time
from pathlib import Path

KILLED_IDX = 1
MAX_NEW = 16
TRACE_OUT = os.path.join("results", "trace_fabric.jsonl")


def _workload(vocab_size, n):
    import numpy as np

    from repro.serving import Request, SamplingParams
    rng = np.random.default_rng(11)
    return [Request(rng.integers(0, vocab_size,
                                 int(rng.integers(3, 10)), dtype=np.int32),
                    SamplingParams(max_new_tokens=MAX_NEW, greedy=True))
            for _ in range(n)]


def run(quick: bool = True, out_path: str = "BENCH_fabric.json"):
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.serving import (HealthConfig, LocalProcessBackend,
                               RequestFailed, Scheduler,
                               collect_fabric_traces,
                               launch_fabric_replicas, shutdown_fabric)
    from repro.serving.fabric import build_engine
    from repro.serving.health import DEAD

    n_requests = 12 if quick else 18
    cfg = get_smoke_config("qwen2-0.5b")
    reqs = _workload(cfg.vocab_size, n_requests)

    # fault-free oracle: the same workload on one in-process scheduler
    # built from the same declarative model spec the workers rebuild —
    # bit-identity across the process boundary is the claim under test
    oracle_sched = Scheduler(build_engine(None))
    oracle_rids = [oracle_sched.submit(r) for r in reqs]
    oracle_sched.run()
    oracle = [oracle_sched.output(r) for r in oracle_rids]

    spool = Path(tempfile.mkdtemp(prefix="fabric-bench-")) / "spool"
    backend = LocalProcessBackend()
    gw = launch_fabric_replicas(
        3, backend, spool, tracing=True,
        health=HealthConfig(degraded_after=20, quarantine_after=40,
                            auto_rejoin=False))
    try:
        t0 = time.perf_counter()
        handles = [gw.submit(r) for r in reqs]
        victim = gw.replicas[KILLED_IDX].scheduler
        killed_name = gw.replicas[KILLED_IDX].name

        # step until the victim's heartbeat shows in-flight work, then
        # SIGKILL it — the kill must land squarely mid-burst, with both
        # admitted decodes and queued submits on the dying process
        killed = False
        for _ in range(200):
            gw.step()
            if victim.active or victim.prefilling:
                backend.kill(victim.handle)
                killed = True
                break
        assert killed, ("the victim never reported in-flight work — "
                        "the burst finished before the kill could land")
        gw.drain()
        wall = time.perf_counter() - t0

        assert gw.health[KILLED_IDX].state == DEAD, (
            "the SIGKILLed worker was never declared dead")
        stats = gw.stats()
        fleet = stats["fleet"]
        assert fleet["failovers"] >= 1

        completed = failed = 0
        bit_identical = True
        for h, ref in zip(handles, oracle):
            out = gw.result(h)
            if isinstance(out, RequestFailed):
                failed += 1
                continue
            completed += 1
            if not np.array_equal(out, ref):
                bit_identical = False
        assert completed == n_requests, (
            f"{n_requests - completed} request(s) lost to the kill")
        assert failed == 0
        assert bit_identical, ("cross-process failover changed greedy "
                               "outputs")

        salvaged = [r for r in gw._requests.values() if r.attempts > 0]
        assert salvaged, ("the kill salvaged nothing — it landed after "
                          "the victim went idle")
        salvage_ok = sum(1 for r in salvaged if r.output is not None)
        salvage_rate = salvage_ok / len(salvaged)
        assert salvage_rate == 1.0, (
            f"only {salvage_ok}/{len(salvaged)} salvaged requests "
            f"completed")

        # recovery wall: the failover event to the last salvaged retire
        events = gw.trace_events()
        fo_ts = next(e["ts"] for e in events
                     if e["kind"] == "replica_failover")
        retried = {(e["replica"], e["rid"]) for e in events
                   if e["kind"] == "replica_retry"}
        recovery_wall = max(
            (e["ts"] for e in events if e["kind"] == "retire"
             and (e["replica"], e["rid"]) in retried),
            default=fo_ts) - fo_ts

        # stop the survivors before collecting: workers export their
        # trace streams (engine steps included) at clean exit, and the
        # merged fleet timeline should carry them — the SIGKILLed
        # worker is the one stream legitimately missing
        shutdown_fabric(gw)
        os.makedirs(os.path.dirname(TRACE_OUT), exist_ok=True)
        n_events = collect_fabric_traces(gw, spool, TRACE_OUT)

        record = {
            "arch": "qwen2-0.5b", "quick": quick,
            "n_requests": n_requests, "replicas": 3,
            "backend": "LocalProcessBackend",
            "killed_replica": killed_name,
            "requests_completed": completed,
            "failed_requests": failed,
            "salvaged_requests": len(salvaged),
            "salvage_success_rate": salvage_rate,
            "failovers": fleet["failovers"],
            "bit_identical_outputs": bit_identical,
            "wall_s": wall,
            "recovery_wall_s": recovery_wall,
            "health": fleet["health"],
            "trace_events": n_events,
            "trace_out": TRACE_OUT,
        }
        from repro.serving.metrics import atomic_write_json
        atomic_write_json(out_path, record)

        rows = [
            ("fabric/kill_1_of_3_processes", wall * 1e6,
             f"{n_requests} requests over 3 subprocess replicas, "
             f"{killed_name} SIGKILLed mid-burst: {completed} completed, "
             f"{failed} failed, {len(salvaged)} salvaged @ "
             f"{salvage_rate:.0%}, bit-identical to in-process oracle, "
             f"results -> {out_path}"),
            ("fabric/recovery", recovery_wall * 1e6,
             f"failover -> last salvaged completion: "
             f"{recovery_wall:.3f}s, merged trace ({n_events} events) "
             f"-> {TRACE_OUT}"),
        ]
        return rows
    finally:
        shutdown_fabric(gw)
        shutil.rmtree(spool.parent, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_fabric.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
