"""Interleaved prefill/decode benchmark: token-budgeted rounds vs
wave-at-once admission under bursts.

The claim the SplitFuse-style scheduler exists to prove: when an
8-deep admission burst of long prompts lands on a replica with running
sequences, bounding each scheduler step to ``prefill_token_budget``
executed prefill tokens (fused with one decode round) keeps the
running sequences' inter-token latency flat — the whole burst no
longer runs every chunked-prefill round between two decode steps — at
the same completed throughput, with greedy outputs bit-identical.

Three runs over the same request stream (2 long-running decodes + 3
bursts of 8 long prompts arriving at fixed step offsets), written to
``BENCH_interleaved.json``:

* **dense**       — dense-layout engine, wave-at-once (oracle);
* **wave**        — paged engine, unbudgeted admission (the PR 4
  shape: a burst's full prefill runs between two decode steps);
* **interleaved** — same paged engine config, ``prefill_token_budget``
  = one compiled ``(Bp, C)`` round per step;
* assertions      — p95 inter-token gap of interleaved <= 1/2 of
  wave-at-once (median over reps), identical completed-request counts
  and total tokens, wall clock within 1.5x, and greedy outputs
  bit-identical dense/wave/interleaved.

  PYTHONPATH=src python -m benchmarks.interleaved_prefill          # smoke
  PYTHONPATH=src python -m benchmarks.interleaved_prefill --full
"""
from __future__ import annotations

import argparse
import time

N_LONG = 2
BURST_DEPTH = 8
BURST_STEPS = (4, 14, 26)
MAX_NEW_LONG = 40
MAX_NEW_BURST = 2


def _workload(cfg):
    import numpy as np
    rng = np.random.default_rng(0)
    longs = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
             for _ in range(N_LONG)]
    bursts = [[rng.integers(0, cfg.vocab_size, 48, dtype=np.int32)
               for _ in range(BURST_DEPTH)]
              for _ in range(len(BURST_STEPS))]
    return longs, bursts


def _serve(engine, cfg, budget):
    """Drive one run: long-runners first, then each 8-deep burst lands
    at its step offset mid-decode.  Returns (outputs in submission
    order, metrics summary, wall seconds)."""
    from repro.serving import Request, SamplingParams, Scheduler
    longs, bursts = _workload(cfg)
    sched = Scheduler(engine, prefill_token_budget=budget)
    rids = [sched.submit(Request(p, SamplingParams(
        max_new_tokens=MAX_NEW_LONG, greedy=True))) for p in longs]
    pending = list(zip(BURST_STEPS, bursts))
    steps = 0
    t0 = time.perf_counter()
    while sched.has_work or pending:
        if pending and steps >= pending[0][0]:
            burst = pending.pop(0)[1]
            rids += [sched.submit(Request(p, SamplingParams(
                max_new_tokens=MAX_NEW_BURST, greedy=True)))
                for p in burst]
        sched.step()
        steps += 1
    wall = time.perf_counter() - t0
    return [sched.output(r) for r in rids], sched.metrics.summary(), wall


def _warmup(engine, cfg):
    """Compile the (Bp, C) prefill round, the decode step, and the
    samplers outside the timed windows."""
    import numpy as np
    from repro.serving import Request, SamplingParams, Scheduler
    rng = np.random.default_rng(1)
    sched = Scheduler(engine, prefill_token_budget=None)
    sched.submit(Request(rng.integers(0, cfg.vocab_size, 48, dtype=np.int32),
                         SamplingParams(max_new_tokens=4, greedy=True)))
    sched.run()


def run(quick: bool = True, out_path: str = "BENCH_interleaved.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ServingEngine

    arch = "qwen2-0.5b"
    # chunk 8: the interleaved per-step prefill quantum is one (4, 8)
    # round, while a whole 8-deep burst of 48-token prompts costs 12
    # such rounds — the wave-at-once stall the budget removes
    block, max_seq_len, slots, prefill_batch, chunk = 16, 64, 12, 4, 8
    budget = prefill_batch * chunk       # one compiled round per step
    reps = 3 if quick else 5             # median de-flakes the ratio

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    num_blocks = slots * (max_seq_len // block)

    def engine(**kw):
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=slots, kv_block_size=block,
                             prefill_chunk=chunk,
                             prefill_batch=prefill_batch, **kw)

    dense_out, dense_sum, _ = _serve(engine(), cfg, None)

    wave_eng = engine(paged=True, num_blocks=num_blocks)
    inter_eng = engine(paged=True, num_blocks=num_blocks)
    _warmup(wave_eng, cfg)
    _warmup(inter_eng, cfg)

    _serve(wave_eng, cfg, None)          # discarded warm rep: the first
    _serve(inter_eng, cfg, budget)       # pass pays allocator/dispatch cost

    ratios, wave_runs, inter_runs = [], [], []
    for _rep in range(reps):
        wave_out, wave_sum, wave_wall = _serve(wave_eng, cfg, None)
        inter_out, inter_sum, inter_wall = _serve(inter_eng, cfg, budget)
        ratios.append(wave_sum["decode_gap_ms"]["p95"]
                      / inter_sum["decode_gap_ms"]["p95"])
        wave_runs.append((wave_sum, wave_wall))
        inter_runs.append((inter_sum, inter_wall))

    n_req = N_LONG + BURST_DEPTH * len(BURST_STEPS)
    for a, b, c in zip(dense_out, wave_out, inter_out):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert (dense_sum["requests_completed"]
            == wave_sum["requests_completed"]
            == inter_sum["requests_completed"] == n_req)
    assert (wave_sum["total_new_tokens"]
            == inter_sum["total_new_tokens"])       # equal throughput...
    wave_wall = sorted(w for _, w in wave_runs)[reps // 2]
    inter_wall = sorted(w for _, w in inter_runs)[reps // 2]
    assert inter_wall <= 1.5 * wave_wall, (
        f"interleaving cost wall clock: {inter_wall:.3f}s vs "
        f"{wave_wall:.3f}s wave-at-once — no longer 'equal throughput'")
    jitter_drop = sorted(ratios)[len(ratios) // 2]
    assert jitter_drop >= 2.0, (
        f"interleaved p95 inter-token gap only {jitter_drop:.2f}x lower "
        f"(median of {[f'{r:.2f}' for r in ratios]}) than wave-at-once "
        f"under an {BURST_DEPTH}-deep burst — the SplitFuse win regressed")

    def mode_record(summary, wall):
        return {
            "decode_gap_ms": summary["decode_gap_ms"],
            "ttft_ms": summary["ttft_ms"],
            "wall_s": wall,
            "tokens_per_s": summary["tokens_per_s"],
            "requests_completed": summary["requests_completed"],
            "decode_steps": summary["decode_steps"],
            "prefill_budget": summary["prefill_budget"],
        }

    record = {
        "arch": arch, "quick": quick, "n_requests": n_req,
        "burst_depth": BURST_DEPTH, "bursts": len(BURST_STEPS),
        "block_size": block, "max_seq_len": max_seq_len,
        "max_slots": slots, "num_blocks": num_blocks,
        "prefill_batch": prefill_batch, "prefill_chunk": chunk,
        "prefill_token_budget": budget,
        "dense": mode_record(dense_sum, 0.0),
        "wave_at_once": mode_record(wave_sum, wave_wall),
        "interleaved": mode_record(inter_sum, inter_wall),
        "p95_gap_drop": jitter_drop,
        "bit_identical_outputs": True,
    }
    record["dense"].pop("wall_s")                   # untimed oracle run
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    wg, ig = wave_sum["decode_gap_ms"], inter_sum["decode_gap_ms"]
    rows = [
        ("interleaved_prefill/wave_at_once", wave_wall * 1e6,
         f"unbudgeted admission: p95 inter-token gap {wg['p95']:.2f} ms "
         f"(max {wg['max']:.2f} ms) under {BURST_DEPTH}-deep bursts"),
        ("interleaved_prefill/interleaved", inter_wall * 1e6,
         f"budget {budget} tok/step: p95 gap {ig['p95']:.2f} ms "
         f"({jitter_drop:.1f}x lower, max {ig['max']:.2f} ms), "
         f"budget utilization "
         f"{inter_sum['prefill_budget']['utilization']:.2f}, "
         f"bit-identical, results -> {out_path}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_interleaved.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
