"""3DGAN physics validation (paper §IV-A / refs [21-22]).

The paper states the 3DGAN's "initial validation ... shows a remarkable
agreement with respect to state-of-the-art Monte Carlo"; the standard
validation observables (from the CERN 3DGAN studies) are:

  * longitudinal shower profile (energy vs depth z),
  * transverse/lateral profile (energy vs radial distance),
  * total deposited energy vs primary energy (sampling-fraction linearity).

This benchmark trains the 3DGAN briefly on the synthetic-MC source, then
compares those observables between generated and "MC" showers: chi2-like
normalized-profile distances and the energy-response correlation.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data import CalorimeterSpec, generate_batch
from repro.models import gan3d as G


def profiles(img: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(longitudinal (G,), lateral (G,)) normalized energy profiles."""
    e = img[..., 0]                                 # (B, X, Y, Z)
    longi = e.sum((1, 2)).mean(0)
    lat = e.sum((2, 3)).mean(0)
    return longi / (longi.sum() + 1e-9), lat / (lat.sum() + 1e-9)


def chi2_distance(p: np.ndarray, q: np.ndarray) -> float:
    return float(0.5 * np.sum((p - q) ** 2 / (p + q + 1e-9)))


def run(train_steps: int = 40, batch: int = 8,
        eval_events: int = 64) -> List[Tuple[str, float, str]]:
    cfg = G.GAN3DConfig(g_fc_ch=6, g_base=16, d_base=8)
    key = jax.random.PRNGKey(0)
    gp = G.init_generator(key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(key, 1), cfg)
    d_opt = optim.rmsprop(5e-4, clip_norm=1.0)
    g_opt = optim.rmsprop(1e-3, clip_norm=1.0)
    ds, gs = d_opt.init(dp), g_opt.init(gp)

    @jax.jit
    def step(dp, ds, gp, gs, batch_, z):
        gd, _ = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch_, z)
        du, ds = d_opt.update(gd, ds, dp)
        dp = optim.apply_updates(dp, du)
        gg, _ = jax.grad(G.g_loss, has_aux=True)(gp, dp, cfg, batch_, z)
        gu, gs = g_opt.update(gg, gs, gp)
        return dp, ds, optim.apply_updates(gp, gu), gs

    spec = CalorimeterSpec()
    t0 = time.time()
    for i in range(train_steps):
        b = {k: jnp.asarray(v)
             for k, v in generate_batch(spec, batch, i).items()}
        key, kz = jax.random.split(key)
        z = jax.random.normal(kz, (batch, cfg.latent_dim))
        dp, ds, gp, gs = step(dp, ds, gp, gs, b, z)
    train_s = time.time() - t0

    # ---- observables --------------------------------------------------------
    mc = generate_batch(spec, eval_events, step=10_000)
    key, kz = jax.random.split(key)
    z = jax.random.normal(kz, (eval_events, cfg.latent_dim))
    fake = np.asarray(G.generator(gp, cfg, z, jnp.asarray(mc["energies"])))

    longi_mc, lat_mc = profiles(mc["images"])
    longi_g, lat_g = profiles(fake)
    chi_l = chi2_distance(longi_mc, longi_g)
    chi_t = chi2_distance(lat_mc, lat_g)
    totals_g = fake.sum((1, 2, 3, 4))
    corr = float(np.corrcoef(mc["energies"], totals_g)[0, 1])
    peak_mc = int(np.argmax(longi_mc))
    peak_g = int(np.argmax(longi_g))

    return [
        ("physics/train", train_s * 1e6 / max(train_steps, 1),
         f"{train_steps} steps"),
        ("physics/longitudinal_chi2", 0.0,
         f"{chi_l:.4f} (0=perfect; <0.5 = qualitatively matching profile)"),
        ("physics/lateral_chi2", 0.0, f"{chi_t:.4f}"),
        ("physics/shower_max_depth", 0.0,
         f"MC z={peak_mc} vs GAN z={peak_g}"),
        ("physics/energy_response_corr", 0.0,
         f"{corr:.3f} (paper: conditioning on primary energy)"),
    ]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
