"""Batched multi-slot prefill benchmark: co-admission vs one-at-a-time.

The claim the paged chunked-prefill path exists to prove: with a queue
of waiting prompts, admitting them as ONE batched chunked-prefill
program per round (KV written straight into pool blocks through the
block tables — no transient dense ``max_seq_len`` stripe) reaches a
far lower mean TTFT than the old one-prompt-per-scheduler-round
admission, at the *identical* KV budget, with greedy outputs
bit-identical across every path.

Four runs over the same request stream, written to
``BENCH_batched_prefill.json``:

* **dense**    — the dense-layout engine (correctness oracle);
* **batched**  — paged engine, ``prefill_batch`` co-admission;
* **serial**   — paged engine, same ``num_blocks``, but
  ``prefill_batch=1`` *and* one admission per scheduler step (the PR 3
  admission shape: each queued prompt waits for every earlier prompt's
  prefill plus a decode round of all live sequences);
* assertions   — outputs bit-identical dense/batched/serial, mean TTFT
  of batched ≤ ½ of serial, zero transient stripe bytes in paged mode,
  and the real-vs-padding prefill token split is exported.

  PYTHONPATH=src python -m benchmarks.batched_prefill          # smoke
  PYTHONPATH=src python -m benchmarks.batched_prefill --full
"""
from __future__ import annotations

import argparse
import time


def _warmup(engine, prompt, max_new):
    """Compile the engine's prefill + decode programs outside the timed
    window (TTFT should measure admission latency, not jit compiles)."""
    from repro.serving import Request, SamplingParams, Scheduler
    sched = Scheduler(engine)
    sched.submit(Request(prompt, SamplingParams(max_new_tokens=max_new,
                                                greedy=True)))
    sched.run()


def _serve(engine, prompts, max_new, max_admissions_per_step=None):
    import numpy as np

    from repro.serving import Request, SamplingParams, Scheduler
    sched = Scheduler(engine,
                      max_admissions_per_step=max_admissions_per_step)
    rids = [sched.submit(Request(p, SamplingParams(max_new_tokens=max_new,
                                                   greedy=True)))
            for p in prompts]
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    ttft = sched.metrics.ttft_s()
    return ([sched.output(r) for r in rids], wall,
            sum(ttft) / len(ttft), sched)


def run(quick: bool = True, out_path: str = "BENCH_batched_prefill.json"):
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving import ServingEngine

    arch = "qwen2-0.5b"
    block = 16
    reps = 3                # median-of-3 de-flakes the wall-clock ratio
    if quick:
        n_requests, max_new = 8, 4
        max_seq_len, slots = 64, 8
        prompt_lens = [8 + (i * 5) % 8 for i in range(n_requests)]
    else:
        reps = 5
        n_requests, max_new = 8, 12
        max_seq_len, slots = 64, 8
        prompt_lens = [8 + (i * 5) % 8 for i in range(n_requests)]

    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in prompt_lens]
    num_blocks = slots * (max_seq_len // block)      # identical KV budget

    def engine(**kw):
        return ServingEngine(cfg, params, max_seq_len=max_seq_len,
                             max_slots=slots, kv_block_size=block, **kw)

    warm = rng.integers(0, cfg.vocab_size, max(prompt_lens), dtype=np.int32)

    dense_eng = engine()
    dense_out, _, _, _ = _serve(dense_eng, prompts, max_new)

    batched_eng = engine(paged=True, num_blocks=num_blocks,
                         prefill_batch=slots)
    _warmup(batched_eng, warm, max_new)
    serial_eng = engine(paged=True, num_blocks=num_blocks, prefill_batch=1)
    _warmup(serial_eng, warm, max_new)

    ratios = []
    for rep in range(reps):
        batched_out, batched_wall, batched_ttft, bsched = _serve(
            batched_eng, prompts, max_new)
        serial_out, serial_wall, serial_ttft, ssched = _serve(
            serial_eng, prompts, max_new, max_admissions_per_step=1)
        ratios.append(serial_ttft / batched_ttft)

    for a, b, c in zip(dense_out, batched_out, serial_out):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert batched_eng.kv.kv_bytes() == serial_eng.kv.kv_bytes()
    # the whole point: no transient dense stripe in paged prefill
    assert batched_eng.transient_prefill_bytes == 0
    assert serial_eng.transient_prefill_bytes == 0
    assert dense_eng.transient_prefill_bytes > 0
    speedup = sorted(ratios)[len(ratios) // 2]       # median over reps
    assert speedup >= 2.0, (
        f"batched co-admission only {speedup:.2f}x (median of "
        f"{[f'{r:.2f}' for r in ratios]}) on mean TTFT "
        f"({batched_ttft * 1e3:.1f} ms vs {serial_ttft * 1e3:.1f} ms) — "
        "the multi-slot prefill win regressed")

    bm = bsched.metrics.summary()["prefill_tokens"]
    record = {
        "arch": arch, "quick": quick, "n_requests": n_requests,
        "queue_depth": n_requests, "block_size": block,
        "max_seq_len": max_seq_len, "max_slots": slots,
        "num_blocks": num_blocks,
        "kv_bytes_budget": batched_eng.kv.kv_bytes(),
        "batched": {"prefill_batch": slots,
                    "mean_ttft_ms": batched_ttft * 1e3,
                    "wall_s": batched_wall,
                    "prefill_tokens": bm,
                    "requests_completed": len(batched_out),
                    "transient_prefill_bytes": 0},
        "serial": {"prefill_batch": 1,
                   "mean_ttft_ms": serial_ttft * 1e3,
                   "wall_s": serial_wall,
                   "prefill_tokens":
                       ssched.metrics.summary()["prefill_tokens"],
                   "requests_completed": len(serial_out),
                   "transient_prefill_bytes": 0},
        "ttft_speedup": speedup,
        "bit_identical_outputs": True,
    }
    # atomic (tmp + os.replace): a benchmark killed mid-write can never
    # leave a truncated BENCH_*.json for run.py --check to choke on
    from repro.serving.metrics import atomic_write_json
    atomic_write_json(out_path, record)

    rows = [
        ("batched_prefill/serial", serial_wall * 1e6,
         f"one-at-a-time admission: mean TTFT "
         f"{serial_ttft * 1e3:.1f} ms at queue depth {n_requests}"),
        ("batched_prefill/batched", batched_wall * 1e6,
         f"co-admission x{slots}: mean TTFT {batched_ttft * 1e3:.1f} ms "
         f"({speedup:.1f}x lower), same {record['kv_bytes_budget']} KV "
         f"bytes, padding fraction {bm['padding_fraction']:.2f}, "
         f"bit-identical, results -> {out_path}"),
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_batched_prefill.json")
    args = ap.parse_args()
    rows = run(quick=not args.full, out_path=args.out)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
