"""RL001 — host-device sync in serving hot paths.

The decode loop's throughput story depends on staying async: one
deliberate host sync per step (reading the sampled token ids) and
nothing else.  A stray ``np.asarray`` / ``.item()`` / ``float()`` on a
device value anywhere in the ``Scheduler.step`` / ``decode_once`` /
``advance_prefill`` call graphs serializes the pipeline and shows up as
inflated inter-token gaps that the runtime profiler can *measure* but
not *explain*.  This rule names the exact line.

Mechanics: roots are ``Scheduler.step`` plus any ``decode_once`` /
``advance_prefill`` / ``_advance_prefill`` def.  Reachability is a
name-based over-approximation (``self.x.foo()`` reaches every def named
``foo`` in the scanned tree) — deliberate: a linter that misses a sync
because it could not resolve a receiver is worse than one that needs an
occasional inline suppression.  Within each reached function a
flow-insensitive taint pass marks names assigned from device-producing
expressions (``jnp.*`` / ``jax.*`` calls, ``.last_logits``), and flags:

* ``jax.block_until_ready`` / ``jax.device_get`` anywhere (sync by
  definition);
* ``.item()`` / ``.tolist()`` method calls;
* ``np.asarray`` / ``np.array`` whose argument is a direct call result
  or a tainted expression;
* ``float()`` / ``int()`` / ``bool()`` on a tainted expression.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.core import (Finding, LintContext, Module, Rule,
                                 attr_chain, register, walk_functions)

ROOT_CLASS_METHODS = {("Scheduler", "step")}
ROOT_NAMES = {"decode_once", "advance_prefill", "_advance_prefill"}

DEVICE_MODULES = {"jnp", "jax", "lax"}
DEVICE_ATTRS = {"last_logits"}
SYNC_CHAINS = {"jax.block_until_ready", "jax.device_get"}
NUMPY_CASTS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "onp.asarray", "onp.array"}
SCALAR_CASTS = {"float", "int", "bool"}


def _is_device_expr(node: ast.AST, tainted: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in DEVICE_ATTRS:
            return True
        return _is_device_expr(node.value, tainted)
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain.split(".", 1)[0] in DEVICE_MODULES:
            return True
        # method chain on a device receiver: x.reshape(...), x.astype(...)
        if isinstance(node.func, ast.Attribute) and \
                _is_device_expr(node.func.value, tainted):
            return True
        return False
    if isinstance(node, ast.BinOp):
        return (_is_device_expr(node.left, tainted)
                or _is_device_expr(node.right, tainted))
    if isinstance(node, ast.UnaryOp):
        return _is_device_expr(node.operand, tainted)
    if isinstance(node, ast.Subscript):
        return _is_device_expr(node.value, tainted)
    if isinstance(node, ast.IfExp):
        return (_is_device_expr(node.body, tainted)
                or _is_device_expr(node.orelse, tainted))
    return False


def _taint_names(fn: ast.FunctionDef) -> Set[str]:
    """Names assigned from device expressions, to a fixpoint (flow
    insensitive: order of assignment does not matter)."""
    tainted: Set[str] = set()
    for _ in range(4):
        grew = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            if node.value is None or \
                    not _is_device_expr(node.value, tainted):
                continue
            for t in targets:
                names = [t] if isinstance(t, ast.Name) else \
                    [e for e in ast.walk(t) if isinstance(e, ast.Name)]
                for n in names:
                    if n.id not in tainted:
                        tainted.add(n.id)
                        grew = True
        if not grew:
            break
    return tainted


def _function_index(modules: List[Module]):
    """name -> [(module, classname, fn)] over every def in the tree."""
    index: Dict[str, List[Tuple[Module, str, ast.FunctionDef]]] = {}
    for mod in modules:
        for cls, fn in walk_functions(mod.tree):
            index.setdefault(fn.name, []).append((mod, cls, fn))
    return index


def _called_names(fn: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute):
                out.add(node.func.attr)
            elif isinstance(node.func, ast.Name):
                out.add(node.func.id)
    return out


@register
class HotPathSyncRule(Rule):
    rule_id = "RL001"
    name = "hot-path-host-sync"
    description = ("host-device synchronization reachable from "
                   "Scheduler.step / decode_once / advance_prefill")

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        index = _function_index(modules)

        # roots + BFS over called names
        work: List[Tuple[Module, str, ast.FunctionDef, str]] = []
        seen: Set[int] = set()
        for name, entries in index.items():
            for mod, cls, fn in entries:
                is_root = ((cls, name) in ROOT_CLASS_METHODS
                           or name in ROOT_NAMES)
                if is_root and id(fn) not in seen:
                    seen.add(id(fn))
                    qual = f"{cls}.{name}" if cls else name
                    work.append((mod, cls, fn, qual))
        reached = []
        while work:
            mod, cls, fn, origin = work.pop()
            reached.append((mod, cls, fn, origin))
            for callee in _called_names(fn):
                for cmod, ccls, cfn in index.get(callee, ()):
                    if id(cfn) not in seen:
                        seen.add(id(cfn))
                        work.append((cmod, ccls, cfn, origin))

        findings: List[Finding] = []
        flagged: Set[Tuple[str, int]] = set()

        def emit(mod, node, msg):
            key = (mod.path, node.lineno)
            if key not in flagged:
                flagged.add(key)
                findings.append(Finding(mod.path, node.lineno,
                                        self.rule_id, msg))

        for mod, cls, fn, origin in reached:
            qual = f"{cls}.{fn.name}" if cls else fn.name
            where = (f"in `{qual}` (hot path via {origin})"
                     if qual != origin else f"in hot path `{qual}`")
            tainted = _taint_names(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                if chain in SYNC_CHAINS:
                    emit(mod, node, f"explicit device sync "
                                    f"`{chain}` {where}")
                    continue
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in ("item", "tolist"):
                    emit(mod, node, f"`.{node.func.attr}()` forces a "
                                    f"host-device sync {where}")
                    continue
                if chain in NUMPY_CASTS and node.args:
                    arg = node.args[0]
                    if isinstance(arg, ast.Call) or \
                            _is_device_expr(arg, tainted):
                        emit(mod, node,
                             f"`{chain}` on a device value forces a "
                             f"host-device sync {where}")
                    continue
                if isinstance(node.func, ast.Name) and \
                        node.func.id in SCALAR_CASTS and node.args and \
                        _is_device_expr(node.args[0], tainted):
                    emit(mod, node,
                         f"`{node.func.id}()` on a device value forces "
                         f"a host-device sync {where}")
        return findings
