"""RL003 — Pallas kernel launch checks.

Pallas failure modes are notoriously late and opaque: an index-map
lambda with the wrong arity fails deep inside tracing, a scratch shape
mismatch OOMs or corrupts VMEM on hardware, and a kernel without an
``interpret=`` escape hatch cannot run in CPU CI at all (the whole test
strategy of this repo — interpret mode on CPU, compiled on TPU —
depends on it).  All three are statically checkable at the
``pl.pallas_call`` site:

* index-map arity: every ``BlockSpec`` index-map lambda must take
  exactly ``grid rank`` parameters — plus ``num_scalar_prefetch`` when
  launched through a ``PrefetchScalarGridSpec`` (the prefetched scalar
  refs are prepended to the index-map arguments).
* VMEM scratch: ``pltpu.VMEM(...)`` entries in ``scratch_shapes`` must
  pass a literal shape tuple and an explicit dtype.
* CPU fallback: the ``pallas_call`` must thread an ``interpret=`` kwarg.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import (Finding, LintContext, Module, Rule,
                                 attr_chain, register)


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _grid_rank(node: ast.AST) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1                          # grid=N is a rank-1 launch
    return None                           # computed elsewhere: skip


def _index_map_arity(node: ast.AST, mod: Module) -> Optional[int]:
    if isinstance(node, ast.Lambda):
        a = node.args
        return len(a.posonlyargs) + len(a.args)
    if isinstance(node, ast.Name):        # def'd index map: resolve local
        for sub in ast.walk(mod.tree):
            if isinstance(sub, ast.FunctionDef) and sub.name == node.id:
                a = sub.args
                return len(a.posonlyargs) + len(a.args)
    return None


def _block_specs(node: ast.AST) -> List[ast.Call]:
    """BlockSpec(...) calls inside an in_specs/out_specs expression."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and \
                attr_chain(sub.func).endswith("BlockSpec"):
            out.append(sub)
    return out


@register
class PallasLaunchRule(Rule):
    rule_id = "RL003"
    name = "pallas-launch-check"
    description = ("BlockSpec index-map arity vs grid rank, VMEM scratch "
                   "shape/dtype, missing interpret= CPU fallback")

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call) and \
                        attr_chain(node.func).endswith("pallas_call"):
                    findings.extend(self._check_call(mod, node))
        return findings

    def _check_call(self, mod: Module, call: ast.Call) -> List[Finding]:
        out: List[Finding] = []

        grid = _kw(call, "grid")
        grid_spec = _kw(call, "grid_spec")
        prefetch = 0
        specs_holder = call                 # where in/out_specs live
        if grid_spec is not None and isinstance(grid_spec, ast.Call):
            specs_holder = grid_spec
            grid = _kw(grid_spec, "grid")
            npf = _kw(grid_spec, "num_scalar_prefetch")
            if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
                prefetch = npf.value
        rank = _grid_rank(grid) if grid is not None else None

        if rank is not None:
            expect = rank + prefetch
            spec_nodes = []
            for kw_name in ("in_specs", "out_specs"):
                v = _kw(specs_holder, kw_name)
                if v is not None:
                    spec_nodes.extend(_block_specs(v))
            for spec in spec_nodes:
                imap = None
                if len(spec.args) >= 2:
                    imap = spec.args[1]
                else:
                    imap = _kw(spec, "index_map")
                if imap is None:
                    continue
                arity = _index_map_arity(imap, mod)
                if arity is not None and arity != expect:
                    extra = (f" + {prefetch} scalar-prefetch arg"
                             f"{'s' if prefetch != 1 else ''}"
                             if prefetch else "")
                    out.append(Finding(
                        mod.path, imap.lineno, self.rule_id,
                        f"BlockSpec index map takes {arity} args but the "
                        f"launch grid has rank {rank}{extra} (expected "
                        f"{expect}) — Pallas will fail at trace time"))

        scratch = _kw(specs_holder, "scratch_shapes")
        if scratch is not None:
            for sub in ast.walk(scratch):
                if not (isinstance(sub, ast.Call)
                        and attr_chain(sub.func).endswith("VMEM")):
                    continue
                shape = sub.args[0] if sub.args else _kw(sub, "shape")
                dtype = (sub.args[1] if len(sub.args) >= 2
                         else _kw(sub, "dtype"))
                if not isinstance(shape, (ast.Tuple, ast.List)):
                    out.append(Finding(
                        mod.path, sub.lineno, self.rule_id,
                        "VMEM scratch shape must be a literal tuple "
                        "(scalar or computed shapes hide rank bugs "
                        "until TPU lowering)"))
                if dtype is None:
                    out.append(Finding(
                        mod.path, sub.lineno, self.rule_id,
                        "VMEM scratch entry is missing an explicit "
                        "dtype"))

        if _kw(call, "interpret") is None:
            out.append(Finding(
                mod.path, call.lineno, self.rule_id,
                "pallas_call without an `interpret=` kwarg cannot fall "
                "back to CPU interpret mode — untestable off-TPU"))
        return out
