"""RL004 — tracing-schema drift in ``serving/``.

The tracing pipeline's contract is a closed event vocabulary:
``EVENT_KINDS`` in :mod:`repro.serving.tracing` is what
``scripts/trace_report.py --validate`` enforces on exported JSONL and
what the Chrome-trace exporter switches on.  A ``kind`` literal that
drifts from the enum produces events that pass silently at emission and
fail (or vanish) at validation/visualization time — exactly the
late-failure shape this linter exists to move earlier.  Ditto the
metrics path: every counter mutation is supposed to flow through the
tracer's single recording path so traces and metrics can never disagree;
a direct ``metrics.record_*`` call in serving code bypasses it.

Checks, scoped to files under a ``serving/`` directory:

* every string literal passed as the first argument of an ``_emit(...)``
  call, or as a ``kind=`` keyword anywhere, must be a member of
  ``EVENT_KINDS`` (recovered from the scanned tree, or injected via
  :class:`LintContext` in tests);
* ``*.metrics.record_*(...)`` calls outside ``tracing.py`` /
  ``metrics.py`` are flagged as tracer bypasses.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.core import (Finding, LintContext, Module, Rule,
                                 attr_chain, register)


def _literal_strings(value: ast.AST,
                     assigned: "dict[str, Set[str]]") -> Set[str]:
    """All string literals reachable from ``value``, resolving bare
    ``Name`` references against previously-seen module-level frozenset
    assignments — so ``EVENT_KINDS = frozenset({...}) | FAULT_EVENT_KINDS``
    recovers the full union, not just the inline half."""
    out: Set[str] = set()
    for sub in ast.walk(value):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
        elif isinstance(sub, ast.Name) and sub.id in assigned:
            out.update(assigned[sub.id])
    return out


def _find_event_kinds(modules: List[Module]) -> Optional[Set[str]]:
    for mod in modules:
        # walk top-level assigns in source order, accumulating each
        # name's literal-string set so later unions can reference it
        assigned: dict = {}
        for node in mod.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            strings = _literal_strings(node.value, assigned)
            for t in node.targets:
                if isinstance(t, ast.Name):
                    assigned[t.id] = strings
        kinds = assigned.get("EVENT_KINDS")
        if kinds:
            return kinds
    return None


def _in_serving(mod: Module) -> bool:
    return "serving/" in mod.path or mod.path.startswith("serving")


@register
class TracingSchemaRule(Rule):
    rule_id = "RL004"
    name = "tracing-schema-drift"
    description = ("event kind literals outside EVENT_KINDS; "
                   "metrics.record_* calls bypassing the tracer")

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        kinds = ctx.event_kinds
        if kinds is None:
            kinds = _find_event_kinds(modules)
        findings: List[Finding] = []
        for mod in modules:
            if not _in_serving(mod):
                continue
            base = mod.path.rsplit("/", 1)[-1]
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = (node.func.attr
                        if isinstance(node.func, ast.Attribute)
                        else node.func.id
                        if isinstance(node.func, ast.Name) else "")
                if kinds is not None:
                    lit: Optional[ast.Constant] = None
                    if name == "_emit" and node.args and \
                            isinstance(node.args[0], ast.Constant) and \
                            isinstance(node.args[0].value, str):
                        lit = node.args[0]
                    for kw in node.keywords:
                        if kw.arg == "kind" and \
                                isinstance(kw.value, ast.Constant) and \
                                isinstance(kw.value.value, str):
                            lit = kw.value
                    if lit is not None and lit.value not in kinds:
                        findings.append(Finding(
                            mod.path, lit.lineno, self.rule_id,
                            f"event kind '{lit.value}' is not in "
                            f"EVENT_KINDS — it will fail trace "
                            f"validation and be dropped by exporters"))
                if name.startswith("record_") and \
                        base not in ("tracing.py", "metrics.py") and \
                        isinstance(node.func, ast.Attribute):
                    chain = attr_chain(node.func)
                    head = chain.rsplit(".", 2)
                    if len(head) >= 2 and head[-2] == "metrics":
                        findings.append(Finding(
                            mod.path, node.lineno, self.rule_id,
                            f"direct `{chain}(...)` bypasses the "
                            f"tracer's single recording path — traces "
                            f"and metrics can disagree; route through "
                            f"the Tracer"))
        return findings
