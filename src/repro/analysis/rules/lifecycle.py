"""RL005 — resource-lifecycle pairing.

The serving stack is built on refcounted pools: KV blocks, prefix-cache
pins, slot allocations.  The pre-PR-3 ``_admit`` pin leak is the
canonical bug shape — an acquisition site whose class has no matching
release path, so the resource count only ever goes up and the pool
starves under sustained load (a leak the invariant tests catch only on
the workloads they happen to run).

The check is class-scoped and receiver-matched: for every acquisition
call (``alloc`` / ``ref`` / ``pin`` / ``fork`` / ``acquire`` families)
on a receiver like ``self.pool`` or a bare local alias, the *same class*
must contain a paired release call (``free`` / ``unref`` / ``unpin`` /
``release`` families) on the *same receiver*.  For ``self.x(...)``
acquisitions, defining the paired method on the class also satisfies the
rule (the release may be driven externally).  Deliberate ownership
transfers — handing a block to another object that releases it — are
exactly what the inline suppression comment is for; the comment then
documents the transfer at the acquisition site.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.core import (Finding, LintContext, Module, Rule,
                                 register)

ACQUIRE_PAIRS: Dict[str, Set[str]] = {
    "alloc": {"free", "release", "dealloc"},
    "alloc_slot": {"free_slot", "release_slot"},
    "ref": {"unref", "deref"},
    "pin": {"unpin", "release"},
    "fork": {"unref", "free", "release"},
    "acquire": {"release"},
}


def _receiver(func: ast.Attribute) -> str:
    """'self.pool' for self.pool.alloc(...), 'pool' for pool.alloc(...),
    'self' for self.alloc(...); '' when the chain is not simple."""
    v = func.value
    if isinstance(v, ast.Name):
        return v.id
    if isinstance(v, ast.Attribute) and isinstance(v.value, ast.Name):
        return f"{v.value.id}.{v.attr}"
    return ""


def _class_calls(cls: ast.ClassDef) -> List[Tuple[str, str, int]]:
    """(receiver, method, lineno) for every simple attribute call in the
    class body, nested functions included."""
    out = []
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute):
            recv = _receiver(node.func)
            if recv:
                out.append((recv, node.func.attr, node.lineno))
    return out


@register
class LifecyclePairingRule(Rule):
    rule_id = "RL005"
    name = "resource-lifecycle-pairing"
    description = ("alloc/ref/pin acquisition sites with no matching "
                   "free/unref/release in the same class")

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    findings.extend(self._check_class(mod, node))
        return findings

    def _check_class(self, mod: Module,
                     cls: ast.ClassDef) -> List[Finding]:
        calls = _class_calls(cls)
        methods = {n.name for n in cls.body
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))}
        # receiver -> set of method names called on it anywhere in class
        called: Dict[str, Set[str]] = {}
        for recv, meth, _ in calls:
            called.setdefault(recv, set()).add(meth)

        out: List[Finding] = []
        seen: Set[Tuple[str, str]] = set()
        for recv, meth, lineno in calls:
            releases = ACQUIRE_PAIRS.get(meth)
            if releases is None or (recv, meth) in seen:
                continue
            seen.add((recv, meth))
            paired = bool(called.get(recv, set()) & releases)
            if not paired and recv == "self":
                # self-acquisition: a defined release method counts (it
                # may be driven by the owner of this object)
                paired = bool(methods & releases)
            if not paired:
                wants = "/".join(sorted(releases))
                out.append(Finding(
                    mod.path, lineno, self.rule_id,
                    f"`{recv}.{meth}(...)` in class `{cls.name}` has no "
                    f"matching `{recv}.{wants}` — leak-shaped unless "
                    f"ownership transfers elsewhere (suppress with a "
                    f"comment saying where)"))
        return out
