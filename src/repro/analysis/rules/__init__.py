"""Rule modules; importing this package registers every rule."""
from repro.analysis.rules import (hot_sync, lifecycle, pallas,  # noqa: F401
                                  recompile, tracing_schema)
