"""RL002 — recompilation hazards in jitted functions.

The static twin of the runtime :class:`RecompilationTracker`
(:mod:`repro.serving.profiling`): that tracker reports post-warm
compiles after they have already burned wall clock; this rule points at
the code shapes that cause them before anything runs.

Per jitted function (``@jax.jit`` / ``@functools.partial(jax.jit, ...)``
decorators and ``jax.jit(fn_or_lambda, ...)`` call sites):

* **value branch** — an ``if``/``while`` whose test reads a *non-static*
  parameter's value.  Under trace this either raises (abstract truth
  value) or, with weak types/static promotion, silently retraces per
  distinct value.  Shape introspection (``p.shape`` / ``p.ndim`` /
  ``p.dtype`` / ``len(p)``) and ``is None`` arms (a deliberate
  trace-per-arity pattern) are exempt.
* **concretization** — ``int()`` / ``float()`` / ``bool()`` /
  ``.item()`` on a non-static parameter inside the traced body.
* **unhashable static** — a parameter named in ``static_argnames`` (or
  indexed by ``static_argnums``) whose default is a mutable literal:
  every call misses the jit cache because the key never hashes equal.
* **mutable closure capture** — the traced body reads a name bound to a
  list/dict/set literal in an enclosing scope; the trace bakes in the
  first value and later mutations are silently ignored (or, for
  container identity keys, retrace per call).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, LintContext, Module, Rule,
                                 attr_chain, register)

SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                    ast.DictComp, ast.SetComp)
JIT_CHAINS = {"jax.jit", "jit"}
PARTIAL_CHAINS = {"functools.partial", "partial"}


def _const_strs(node) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _const_ints(node) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)]
    return []


def _jit_static(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names.update(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            nums.update(_const_ints(kw.value))
    return names, nums


def _params(fn) -> List[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _param_defaults(fn) -> Dict[str, ast.AST]:
    a = fn.args
    out: Dict[str, ast.AST] = {}
    pos = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
    for name, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        out[name] = default
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            out[p.arg] = d
    return out


class _JitTarget:
    def __init__(self, fn, static_names: Set[str], static_nums: Set[int],
                 enclosing_mutables: Dict[str, int]):
        self.fn = fn                       # FunctionDef or Lambda
        pos = ([p.arg for p in fn.args.posonlyargs]
               + [p.arg for p in fn.args.args])
        self.static = set(static_names)
        self.static.update(pos[i] for i in static_nums if i < len(pos))
        # name -> lineno of the mutable-literal binding it would capture
        self.enclosing_mutables = enclosing_mutables

    @property
    def label(self) -> str:
        return getattr(self.fn, "name", "<lambda>")


def _collect_targets(mod: Module) -> List[_JitTarget]:
    """One pass with an explicit scope stack: find jit-decorated defs and
    jax.jit(...) call sites, remembering which enclosing names are bound
    to mutable literals (for the closure-capture check)."""
    targets: List[_JitTarget] = []
    # all defs by name (module-wide) for jax.jit(name) resolution
    defs_by_name: Dict[str, List[ast.AST]] = {}

    def index_defs(node):
        for child in ast.walk(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(child.name, []).append(child)
    index_defs(mod.tree)

    claimed: Set[int] = set()

    def mutable_bindings(scope_node) -> Dict[str, int]:
        out: Dict[str, int] = {}
        body = scope_node.body if hasattr(scope_node, "body") else []
        for stmt in body if isinstance(body, list) else []:
            if isinstance(stmt, ast.Assign) and \
                    isinstance(stmt.value, MUTABLE_LITERALS):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = stmt.lineno
        return out

    def visit(node, scope_mutables: Dict[str, int]):
        here = dict(scope_mutables)
        here.update(mutable_bindings(node))

        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sn, sv = set(), set()
                jitted = False
                for dec in child.decorator_list:
                    chain = attr_chain(dec)
                    if chain in JIT_CHAINS:
                        jitted = True
                    elif isinstance(dec, ast.Call):
                        dchain = attr_chain(dec.func)
                        if dchain in JIT_CHAINS:
                            jitted = True
                            n, v = _jit_static(dec)
                            sn |= n
                            sv |= v
                        elif dchain in PARTIAL_CHAINS and dec.args and \
                                attr_chain(dec.args[0]) in JIT_CHAINS:
                            jitted = True
                            n, v = _jit_static(dec)
                            sn |= n
                            sv |= v
                if jitted and id(child) not in claimed:
                    claimed.add(id(child))
                    targets.append(_JitTarget(child, sn, sv, here))
                visit(child, here)
            else:
                visit(child, here)

        # jax.jit(fn_or_lambda, ...) call sites in this scope's direct body
        for stmt in getattr(node, "body", []) \
                if isinstance(getattr(node, "body", None), list) else []:
            for sub in ast.walk(stmt):
                if not (isinstance(sub, ast.Call)
                        and attr_chain(sub.func) in JIT_CHAINS
                        and sub.args):
                    continue
                sn, sv = _jit_static(sub)
                arg = sub.args[0]
                fns: List[ast.AST] = []
                if isinstance(arg, ast.Lambda):
                    fns = [arg]
                elif isinstance(arg, ast.Name):
                    fns = defs_by_name.get(arg.id, [])
                for fn in fns:
                    if id(fn) not in claimed:
                        claimed.add(id(fn))
                        targets.append(_JitTarget(fn, sn, sv, here))

    visit(mod.tree, {})
    return targets


def _locals_of(fn) -> Set[str]:
    out: Set[str] = set(_params(fn))
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        out.add(n.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(node.name)
        elif isinstance(node, (ast.For, ast.comprehension)):
            tgt = node.target
            for n in ast.walk(tgt):
                if isinstance(n, ast.Name):
                    out.add(n.id)
    return out


def _value_branch_params(test: ast.AST, nonstatic: Set[str]) -> Set[str]:
    """Non-static param names whose runtime *value* the test reads."""
    hits: Set[str] = set()

    def scan(node):
        if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
            return                        # shape/dtype introspection: fine
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain == "len" or chain.endswith(".len"):
                return
            for a in node.args:
                scan(a)
            return
        if isinstance(node, ast.Compare):
            none_ops = all(isinstance(op, (ast.Is, ast.IsNot))
                           for op in node.ops)
            none_cmps = all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators)
            if none_ops and none_cmps:
                return                    # `x is (not) None`: arity trace
        if isinstance(node, ast.Name) and node.id in nonstatic:
            hits.add(node.id)
        for child in ast.iter_child_nodes(node):
            scan(child)

    scan(test)
    return hits


@register
class RecompileHazardRule(Rule):
    rule_id = "RL002"
    name = "jit-recompile-hazard"
    description = ("Python-value branches, concretization, unhashable "
                   "statics, and mutable closure capture in jitted "
                   "functions")

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            for tgt in _collect_targets(mod):
                findings.extend(self._check(mod, tgt))
        return findings

    def _check(self, mod: Module, tgt: _JitTarget) -> List[Finding]:
        out: List[Finding] = []
        fn = tgt.fn
        params = set(_params(fn))
        nonstatic = params - tgt.static

        # unhashable static defaults
        for name, default in _param_defaults(fn).items():
            if name in tgt.static and isinstance(default, MUTABLE_LITERALS):
                out.append(Finding(
                    mod.path, default.lineno, self.rule_id,
                    f"jitted `{tgt.label}`: static arg `{name}` has a "
                    f"mutable (unhashable) default — every call misses "
                    f"the jit cache"))

        body = getattr(fn, "body", fn.body if hasattr(fn, "body") else [])
        body_nodes = body if isinstance(body, list) else [body]

        for stmt in body_nodes:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.If, ast.While)):
                    for name in sorted(
                            _value_branch_params(node.test, nonstatic)):
                        out.append(Finding(
                            mod.path, node.lineno, self.rule_id,
                            f"jitted `{tgt.label}`: branch on runtime "
                            f"value of arg `{name}` — traces fail on "
                            f"abstract values or retrace per value; "
                            f"hoist it or mark it static"))
                elif isinstance(node, ast.Call):
                    if isinstance(node.func, ast.Name) and \
                            node.func.id in ("int", "float", "bool") and \
                            node.args and \
                            isinstance(node.args[0], ast.Name) and \
                            node.args[0].id in nonstatic:
                        out.append(Finding(
                            mod.path, node.lineno, self.rule_id,
                            f"jitted `{tgt.label}`: "
                            f"`{node.func.id}({node.args[0].id})` "
                            f"concretizes a traced arg"))
                    elif isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "item" and \
                            isinstance(node.func.value, ast.Name) and \
                            node.func.value.id in nonstatic:
                        out.append(Finding(
                            mod.path, node.lineno, self.rule_id,
                            f"jitted `{tgt.label}`: "
                            f"`{node.func.value.id}.item()` concretizes "
                            f"a traced arg"))

        # mutable closure capture
        if tgt.enclosing_mutables:
            bound = _locals_of(fn)
            reported: Set[str] = set()
            for stmt in body_nodes:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Name) and \
                            isinstance(node.ctx, ast.Load) and \
                            node.id in tgt.enclosing_mutables and \
                            node.id not in bound and \
                            node.id not in reported:
                        reported.add(node.id)
                        out.append(Finding(
                            mod.path, node.lineno, self.rule_id,
                            f"jitted `{tgt.label}` closes over mutable "
                            f"`{node.id}` (bound at line "
                            f"{tgt.enclosing_mutables[node.id]}) — the "
                            f"trace bakes in its first value; later "
                            f"mutations are silently ignored"))
        return out
