"""repro-lint: JAX/Pallas-aware static analysis for the serving stack.

The static counterpart to the runtime observatory
(:mod:`repro.serving.profiling`): where the
:class:`~repro.serving.profiling.RecompilationTracker` catches shape
churn *after* it has burned compile time, these rules catch the hazard
classes *before* the code runs — host-device syncs in the decode hot
path, recompilation-shaped Python in jitted functions, Pallas grid /
BlockSpec mismatches, tracing-schema drift, and leak-shaped resource
lifecycles.  See ``src/repro/analysis/README.md`` for the rule catalog
and the baseline/suppression workflow.

Public surface:

* :func:`lint_paths` — run the rule set over files/directories and get a
  :class:`LintResult` back (the API ``scripts/lint.py`` and the fixture
  tests drive).
* :class:`Finding`, :class:`LintResult`, :class:`LintContext` — the data
  model.
* :func:`all_rules` — the registered rule instances, sorted by rule id.
"""
from repro.analysis.core import (Finding, LintContext, LintResult, Module,
                                 Rule, all_rules, lint_paths, register)

__all__ = ["Finding", "LintContext", "LintResult", "Module", "Rule",
           "all_rules", "lint_paths", "register"]
