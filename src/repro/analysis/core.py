"""repro-lint core: module loading, suppressions, rule registry, driver.

Everything here is plain ``ast`` — no imports of the code under analysis,
so the linter can run on a tree whose dependencies are absent (the same
early-failure philosophy as the source paper's build-time checks: find
the problem before anything executes).

A *rule* is an object with a stable ``rule_id`` (``RLxxx``), a one-line
``description``, and ``run(modules, ctx) -> List[Finding]``.  Rules see
every parsed module at once so cross-file analyses (call graphs, the
``EVENT_KINDS`` schema) need no side channel.  Findings are filtered
through per-line ``# repro-lint: disable=RULE`` suppressions before they
reach the reporter; the committed baseline (``scripts/lint_baseline.json``)
is applied one level up, in :mod:`repro.analysis.baseline`.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set

# matches anywhere in a line: trailing same-line comment or a whole
# comment line.  ``disable=all`` mutes every rule.
_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_]+"
                          r"(?:\s*,\s*[A-Za-z0-9_]+)*)")


@dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: ``path:line  RLxxx  message``."""
    path: str       # root-relative posix path
    line: int       # 1-indexed
    rule_id: str
    message: str


@dataclass
class Module:
    """A parsed source file plus its suppression map."""
    path: str                        # root-relative posix path
    tree: ast.Module
    lines: List[str]                 # raw source, lines[i] is line i+1
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def suppressed(self, line: int, rule_id: str) -> bool:
        rules = self.suppressions.get(line, ())
        return "all" in rules or rule_id in rules


def _parse_suppressions(lines: List[str]) -> Dict[int, Set[str]]:
    """``# repro-lint: disable=RL001[,RL002]`` mutes the rule(s) on its
    own line; a comment-only suppression line also covers the line below
    it (so multi-line statements can carry the marker above them)."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if text.lstrip().startswith("#"):
            out.setdefault(i + 1, set()).update(rules)
    return out


@dataclass
class LintContext:
    """Cross-rule shared state.

    ``event_kinds`` is the tracing schema RL004 validates against.  When
    ``None`` the rule recovers it from the scanned tree (the module that
    assigns ``EVENT_KINDS``); tests inject a small set directly.
    """
    root: Path
    event_kinds: Optional[Set[str]] = None


class Rule:
    """Base class; subclasses set the class attrs and implement run()."""
    rule_id: str = ""
    name: str = ""
    description: str = ""

    def run(self, modules: List[Module],
            ctx: LintContext) -> List[Finding]:
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(rule_cls):
    """Class decorator: instantiate and index by rule_id."""
    rule = rule_cls()
    assert rule.rule_id and rule.rule_id not in _REGISTRY, rule.rule_id
    _REGISTRY[rule.rule_id] = rule
    return rule_cls


def all_rules() -> List[Rule]:
    # importing the package triggers every @register decorator
    from repro.analysis import rules as _rules  # noqa: F401
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# -- driver -----------------------------------------------------------------

def collect_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    seen: Set[Path] = set()
    for p in paths:
        batch = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in batch:
            if f.suffix != ".py":
                continue
            r = f.resolve()
            if r not in seen:
                seen.add(r)
                files.append(f)
    return files


def load_module(path: Path, root: Path) -> Optional[Module]:
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None                     # not lintable; pytest owns syntax
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    lines = src.splitlines()
    return Module(rel, tree, lines, _parse_suppressions(lines))


@dataclass
class LintResult:
    findings: List[Finding]          # live (not suppressed) findings
    suppressed: List[Finding]        # muted by an inline disable comment
    modules: Dict[str, Module]       # path -> Module (for fingerprints)


def lint_paths(paths: Sequence, *, root=None, rules=None,
               event_kinds: Optional[Set[str]] = None) -> LintResult:
    """Parse every ``*.py`` under ``paths`` and run the rule set.

    ``root`` anchors the relative paths findings are reported under
    (default: cwd).  ``rules`` restricts the run to a subset (default:
    every registered rule); ``event_kinds`` feeds RL004 a schema
    directly instead of recovering it from the tree.
    """
    root = Path(root) if root is not None else Path.cwd()
    mods = [m for m in (load_module(f, root)
                        for f in collect_files([Path(p) for p in paths]))
            if m is not None]
    ctx = LintContext(root=root, event_kinds=event_kinds)
    active = list(rules) if rules is not None else all_rules()
    by_path = {m.path: m for m in mods}
    live: List[Finding] = []
    muted: List[Finding] = []
    for rule in active:
        for f in rule.run(mods, ctx):
            mod = by_path.get(f.path)
            if mod is not None and mod.suppressed(f.line, f.rule_id):
                muted.append(f)
            else:
                live.append(f)
    live.sort()
    muted.sort()
    return LintResult(live, muted, by_path)


# -- small AST helpers shared by the rules ----------------------------------

def attr_chain(node: ast.AST) -> str:
    """Dotted-name text of a Name/Attribute chain: ``jax.block_until_ready``
    -> that string; anything non-trivial in the chain -> '' (unknown)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    """Last path component of the called thing: ``self.pool.alloc(...)``
    -> 'alloc', ``free(...)`` -> 'free'."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def walk_functions(tree: ast.Module):
    """Yield (classname_or_None, FunctionDef) for every def in a module,
    including nested ones (classname is the nearest enclosing class)."""
    def visit(node, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield cls, child
                yield from visit(child, cls)
            else:
                yield from visit(child, cls)
    yield from visit(tree, None)
