"""Baseline workflow: pre-existing findings warn, new findings fail.

A baseline entry fingerprints a finding by ``(rule_id, path, stripped
source line text)`` rather than by line *number*, so unrelated edits that
shift code up or down don't invalidate the whole file.  Duplicate
fingerprints are counted (multiset semantics): two identical findings on
two identical lines need two baseline entries.

``scripts/lint.py --fix-baseline`` regenerates the file deliberately;
CI only ever reads it.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.core import Finding, Module

BASELINE_VERSION = 1

Key = Tuple[str, str, str]           # (rule_id, path, line text)


def fingerprint(finding: Finding, modules: Dict[str, Module]) -> Key:
    mod = modules.get(finding.path)
    text = mod.line_text(finding.line) if mod is not None else ""
    return (finding.rule_id, finding.path, text)


def load(path) -> List[Key]:
    path = Path(path)
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return [(e["rule"], e["path"], e["text"])
            for e in data.get("findings", [])]


def save(path, findings: List[Finding],
         modules: Dict[str, Module]) -> None:
    entries = [{"rule": r, "path": p, "text": t}
               for r, p, t in sorted(fingerprint(f, modules)
                                     for f in findings)]
    payload = {"version": BASELINE_VERSION,
               "comment": "accepted pre-existing repro-lint findings; "
                          "regenerate with scripts/lint.py --fix-baseline",
               "findings": entries}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def split(findings: List[Finding], baseline: List[Key],
          modules: Dict[str, Module]
          ) -> Tuple[List[Finding], List[Finding], List[Key]]:
    """Partition current findings into (new, baselined) and report the
    stale baseline entries that no longer match anything (candidates for
    a --fix-baseline cleanup)."""
    budget = Counter(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:                       # sorted upstream: stable
        key = fingerprint(f, modules)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            old.append(f)
        else:
            new.append(f)
    stale = sorted(budget.elements())
    return new, old, stale
