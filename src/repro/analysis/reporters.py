"""Text and JSON reporters for repro-lint results."""
from __future__ import annotations

import json
from typing import List

from repro.analysis.core import Finding


def render_text(new: List[Finding], baselined: List[Finding],
                suppressed_count: int, stale_count: int) -> str:
    out: List[str] = []
    for f in baselined:
        out.append(f"{f.path}:{f.line}: {f.rule_id} [baseline] {f.message}")
    for f in new:
        out.append(f"{f.path}:{f.line}: {f.rule_id} {f.message}")
    summary = (f"repro-lint: {len(new)} new, {len(baselined)} baselined, "
               f"{suppressed_count} suppressed")
    if stale_count:
        summary += (f", {stale_count} stale baseline "
                    f"entr{'y' if stale_count == 1 else 'ies'} "
                    f"(run --fix-baseline)")
    out.append(summary)
    return "\n".join(out)


def render_json(new: List[Finding], baselined: List[Finding],
                suppressed: List[Finding], stale_count: int) -> str:
    def enc(f: Finding, status: str) -> dict:
        return {"path": f.path, "line": f.line, "rule": f.rule_id,
                "message": f.message, "status": status}
    payload = {
        "findings": ([enc(f, "new") for f in new]
                     + [enc(f, "baseline") for f in baselined]),
        "suppressed": [enc(f, "suppressed") for f in suppressed],
        "summary": {"new": len(new), "baselined": len(baselined),
                    "suppressed": len(suppressed),
                    "stale_baseline": stale_count},
    }
    return json.dumps(payload, indent=2, sort_keys=True)
