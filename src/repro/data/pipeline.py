"""Sharded data pipeline.

On SuperMUC-NG the paper reads the CLIC calorimeter HDF5 shards from GPFS;
here the pipeline abstraction is the same (sharded sources -> per-rank
iterator -> host-to-device batches) with a synthetic token source standing
in for tokenized text and ``repro.data.calorimeter`` generating the 3DGAN
shower images.

Design points that matter for the distributed runtime:
  * every rank reads only its shard (``shard(rank, world_size)``) — the
    paper's one-rank-per-node layout;
  * batches are yielded as numpy and placed onto the mesh with
    ``jax.device_put(batch, NamedSharding(mesh, P("data", ...)))`` by the
    trainer, so host->device transfer happens once per step;
  * deterministic: seeded per (epoch, step, rank), so restarts from a
    checkpoint replay identically.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class TokenDatasetSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: a noisy Markov chain so loss is learnable (the
    # smoke-train examples show loss decreasing on it)
    markov_order: int = 1
    noise: float = 0.3


class SyntheticTokenSource:
    """Deterministic synthetic token stream with learnable structure."""

    def __init__(self, spec: TokenDatasetSpec, rank: int = 0,
                 world_size: int = 1):
        assert spec.global_batch % world_size == 0
        self.spec = spec
        self.rank = rank
        self.world_size = world_size
        self.local_batch = spec.global_batch // world_size
        rng = np.random.default_rng(spec.seed)
        # fixed random transition table: next ~ P[cur]
        self._table = rng.permutation(spec.vocab_size)

    def batch(self, step: int, epoch: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.spec.seed, epoch, step, self.rank))
        B, S, V = self.local_batch, self.spec.seq_len, self.spec.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        for t in range(1, S):
            follow = self._table[toks[:, t - 1]]
            noise = rng.integers(0, V, B)
            toks[:, t] = np.where(rng.random(B) < self.spec.noise,
                                  noise, follow)
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class ShardedLoader:
    """Assembles per-rank sources into a global-batch iterator and places
    batches on the mesh (used by the pjit trainer; the hvd trainer keeps
    per-rank numpy batches, matching the MPI layout)."""

    def __init__(self, spec: TokenDatasetSpec, mesh=None, batch_axes=("data",)):
        self.spec = spec
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.source = SyntheticTokenSource(spec)

    def batch(self, step: int):
        host = self.source.batch(step)
        if self.mesh is None:
            return host
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = NamedSharding(self.mesh, P(self.batch_axes))
        return jax.tree.map(lambda a: jax.device_put(a, sh), host)
