from repro.data.calorimeter import CalorimeterSpec, CalorimeterSource, generate_batch
from repro.data.pipeline import ShardedLoader, SyntheticTokenSource, TokenDatasetSpec
