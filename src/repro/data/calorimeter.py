"""Synthetic CLIC calorimeter shower generator (the 3DGAN training data).

The paper's dataset: electromagnetic showers in a 25x25x25-cell LCD
calorimeter grid, one electron per event, conditioned on the primary
particle energy [21-24].  We generate physically-shaped synthetic events:
a longitudinal gamma-like energy-deposition profile along z with lateral
Gaussian spread (Moliere-radius-style), total deposition proportional to
the primary energy — enough structure for the GAN losses, the energy
regressor and the physics-validation benchmark to be meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass
class CalorimeterSpec:
    grid: int = 25
    e_min: float = 10.0      # GeV
    e_max: float = 500.0
    seed: int = 0


def generate_batch(spec: CalorimeterSpec, batch: int, step: int = 0,
                   rank: int = 0) -> Dict[str, np.ndarray]:
    """Returns {"images": (B, G, G, G, 1) f32, "energies": (B,) f32}."""
    rng = np.random.default_rng((spec.seed, step, rank))
    G = spec.grid
    e = rng.uniform(spec.e_min, spec.e_max, batch).astype(np.float32)

    z = np.arange(G, dtype=np.float32)
    # longitudinal gamma profile: t^a * exp(-b t); shower max scales ~ log E
    a = 2.0 + 0.5 * np.log(e / 10.0)[:, None]
    b = 0.5
    prof = np.power(z[None] + 0.5, a) * np.exp(-b * z[None])     # (B, G)
    prof /= prof.sum(axis=1, keepdims=True)

    xy = np.arange(G, dtype=np.float32) - (G - 1) / 2
    # lateral spread narrows with depth-weighted core + halo
    sigma = rng.uniform(1.2, 1.8, batch).astype(np.float32)[:, None]
    lat = np.exp(-0.5 * (xy[None] / sigma) ** 2)                 # (B, G)
    lat /= lat.sum(axis=1, keepdims=True)

    img = (e[:, None, None, None]
           * lat[:, :, None, None] * lat[:, None, :, None] * prof[:, None, None, :])
    # cell-level fluctuation + sparsification (calorimeter noise floor)
    img = img * rng.gamma(4.0, 0.25, img.shape).astype(np.float32)
    img[img < 1e-4] = 0.0
    return {"images": img[..., None].astype(np.float32), "energies": e}


class CalorimeterSource:
    def __init__(self, spec: CalorimeterSpec, batch: int, rank: int = 0,
                 world_size: int = 1):
        self.spec = spec
        self.local_batch = batch // world_size
        self.rank = rank

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        return generate_batch(self.spec, self.local_batch, step, self.rank)
