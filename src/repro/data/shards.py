"""Sharded on-disk dataset format (the paper's CLIC-HDF5-on-GPFS analogue).

The paper's 3DGAN reads electron-shower events from HDF5 shards on the
GPFS parallel filesystem; each MPI rank reads its own subset.  This module
implements the same contract with an npz-based shard format:

  dataset_dir/
      index.json        (shard list, per-shard counts, schema, fingerprint)
      shard_00000.npz   (columnar arrays)
      ...

* ``write_dataset`` streams batches from any generator into fixed-size
  shards with a fingerprinted index (atomic rename, like the checkpoints).
* ``ShardedDataset`` gives each rank a disjoint shard subset
  (round-robin, the paper's one-rank-per-node layout), per-epoch shard
  shuffling with a seeded rng, and batched iteration with wraparound.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np


def write_dataset(out_dir: Path, batches: Iterator[Dict[str, np.ndarray]],
                  *, events_per_shard: int = 1024,
                  max_events: Optional[int] = None) -> Path:
    out_dir = Path(out_dir)
    tmp = out_dir.with_name(out_dir.name + ".tmp")
    tmp.mkdir(parents=True, exist_ok=True)

    buf: Dict[str, List[np.ndarray]] = {}
    shards = []
    total = 0

    def flush():
        nonlocal buf
        if not buf:
            return
        arrays = {k: np.concatenate(v) for k, v in buf.items()}
        n = len(next(iter(arrays.values())))
        name = f"shard_{len(shards):05d}.npz"
        np.savez(tmp / name, **arrays)
        digest = hashlib.sha256((tmp / name).read_bytes()).hexdigest()[:16]
        shards.append({"file": name, "events": n, "sha256_16": digest})
        buf = {}

    for batch in batches:
        n = len(next(iter(batch.values())))
        for k, v in batch.items():
            buf.setdefault(k, []).append(np.asarray(v))
        total += n
        if sum(len(a) for a in buf[next(iter(buf))]) >= events_per_shard:
            flush()
        if max_events and total >= max_events:
            break
    flush()

    schema = {}
    if shards:
        probe = np.load(tmp / shards[0]["file"])
        schema = {k: {"shape": list(probe[k].shape[1:]),
                      "dtype": str(probe[k].dtype)} for k in probe.files}
    index = {"version": 1, "total_events": total, "shards": shards,
             "schema": schema}
    (tmp / "index.json").write_text(json.dumps(index, indent=2))
    if out_dir.exists():
        import shutil
        shutil.rmtree(out_dir)
    os.rename(tmp, out_dir)
    return out_dir


class ShardedDataset:
    """Per-rank reader over a written dataset."""

    def __init__(self, path: Path, rank: int = 0, world_size: int = 1,
                 seed: int = 0):
        self.path = Path(path)
        self.index = json.loads((self.path / "index.json").read_text())
        self.rank, self.world_size, self.seed = rank, world_size, seed
        self.my_shards = [s for i, s in enumerate(self.index["shards"])
                          if i % world_size == rank]
        if not self.my_shards:
            raise ValueError(f"rank {rank}: no shards "
                             f"({len(self.index['shards'])} total)")

    @property
    def local_events(self) -> int:
        return sum(s["events"] for s in self.my_shards)

    def verify(self) -> bool:
        for s in self.my_shards:
            digest = hashlib.sha256(
                (self.path / s["file"]).read_bytes()).hexdigest()[:16]
            if digest != s["sha256_16"]:
                raise IOError(f"shard {s['file']} corrupt "
                              f"({digest} != {s['sha256_16']})")
        return True

    def _load(self, shard) -> Dict[str, np.ndarray]:
        with np.load(self.path / shard["file"]) as z:
            return {k: z[k] for k in z.files}

    def epoch(self, epoch: int, batch_size: int) \
            -> Iterator[Dict[str, np.ndarray]]:
        """Batched iteration over this rank's shards (seeded shuffle)."""
        rng = np.random.default_rng((self.seed, epoch, self.rank))
        order = rng.permutation(len(self.my_shards))
        carry: Dict[str, List[np.ndarray]] = {}
        carried = 0
        for si in order:
            data = self._load(self.my_shards[si])
            perm = rng.permutation(len(next(iter(data.values()))))
            data = {k: v[perm] for k, v in data.items()}
            for k, v in data.items():
                carry.setdefault(k, []).append(v)
            carried += len(perm)
            while carried >= batch_size:
                merged = {k: np.concatenate(v) for k, v in carry.items()}
                yield {k: v[:batch_size] for k, v in merged.items()}
                carry = {k: [v[batch_size:]] for k, v in merged.items()}
                carried -= batch_size
