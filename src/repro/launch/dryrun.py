import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) pair this lowers AND compiles the
appropriate step program (train_step / prefill / serve_step) against the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — using
ShapeDtypeStruct inputs only (no allocation), then records:

  * memory_analysis(): per-device bytes (proves it fits 16 GB HBM),
  * cost_analysis(): HLO FLOPs / bytes (roofline compute & memory terms),
  * collective bytes parsed from the compiled HLO text (roofline
    collective term).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np


def _cost_dict(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on current jax but a
    one-element list of dicts on older releases — normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


# ---------------------------------------------------------------------------
# Collective-byte accounting from HLO text
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op (per device)."""
    # strip /*index=N*/ comments: the '=' inside breaks the shape matcher
    hlo_text = re.sub(r"/\*.*?\*/", "", hlo_text)
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        # avoid double counting start/done pairs: skip -done (its operand is
        # the -start tuple)
        full = m.group(0)
        if "-done(" in full:
            continue
        out[op] += _shape_bytes(shape_str)
    return out


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,{}\s]*)\}\}?")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((?P<rest>.*)$", re.M)


def collective_bytes_by_scope(hlo_text: str, pod_size: int = 256) -> Dict[str, int]:
    """Split collective bytes into intra-pod vs inter-pod traffic by whether
    any replica group spans the pod boundary (device id // pod_size)."""
    hlo_text = re.sub(r"/\*.*?\*/", "", hlo_text)
    out = {"intra_pod": 0, "inter_pod": 0}
    for m in _LINE_RE.finditer(hlo_text):
        shape_str = m.group(1)
        rest = m.group("rest")
        nbytes = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(rest)
        scope = "intra_pod"
        if gm:
            for grp in gm.group(1).split("},{"):
                ids = [int(t) for t in re.findall(r"\d+", grp)]
                if ids and len({i // pod_size for i in ids}) > 1:
                    scope = "inter_pod"
                    break
        elif "collective-permute" in m.group(2):
            scope = "intra_pod"
        out[scope] += nbytes
    return out


# ---------------------------------------------------------------------------
# The dry-run itself
# ---------------------------------------------------------------------------

def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
                strategy: Optional[str] = None, unrolled: bool = False,
                verbose: bool = True) -> Dict:
    """unrolled=True lowers with the layer loop unrolled and attention
    unchunked, so cost_analysis() FLOPs/bytes and the HLO-text collective
    bytes are exact (XLA counts a while-loop body once, not x trip-count).
    The scanned version stays the canonical compile-feasibility artifact."""
    from repro.configs import get_config, default_strategy
    from repro.configs.base import SHAPES, input_specs, shape_skips
    from repro.distributed import stepfn
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skips(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": skip}
    if unrolled:
        cfg = cfg.with_(scan_layers=False, attn_q_chunk=0)
    strategy = strategy or default_strategy(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    jitted, structs = stepfn.make_step_for_shape(cfg, mesh, strategy, shape)
    with mesh, jax.transfer_guard("disallow"):
        lowered = jitted.lower(*structs)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "strategy": strategy, "multi_pod": multi_pod, "chips": n_chips,
        "unrolled": unrolled,
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "collective_bytes_total": int(sum(coll.values())),
        "peak_memory_per_device": int(getattr(mem, "peak_memory_in_bytes", -1)),
        "argument_size": int(getattr(mem, "argument_size_in_bytes", -1)),
        "output_size": int(getattr(mem, "output_size_in_bytes", -1)),
        "temp_size": int(getattr(mem, "temp_size_in_bytes", -1)),
    }
    if verbose:
        print(f"[{arch} x {shape_name} | {'2x16x16' if multi_pod else '16x16'}"
              f" | {strategy}] compile {rec['compile_s']}s  "
              f"flops/dev {rec['flops']:.3e}  bytes/dev {rec['bytes_accessed']:.3e}  "
              f"coll/dev {rec['collective_bytes_total']:.3e}  "
              f"peak-mem/dev {rec['peak_memory_per_device']/2**30:.2f} GiB")
        print("  memory_analysis:", mem)
    return rec


# ---------------------------------------------------------------------------
# Roofline costs via layer-linearity extrapolation
# ---------------------------------------------------------------------------
#
# XLA's cost_analysis counts a while-loop body ONCE (not x trip count), and
# fully unrolling 62-80 layer configs takes tens of minutes on one CPU core.
# Layers are homogeneous, so every cost term is affine in the number of scan
# groups G:  cost(G) = fixed + G * per_group.  We compile the UNROLLED
# program at G=1 and G=2 (seconds each) and extrapolate exactly:
#     cost(G_target) = cost1 + (G_target - 1) * (cost2 - cost1)
# Validated against a full 26-layer unroll in tests/test_dryrun.py.

def _group_counts(cfg):
    """(G_target, cfg_at_1_group, cfg_at_2_groups)."""
    from repro.models.transformer import layer_pattern
    if cfg.family == "hybrid":
        E, L = cfg.hybrid_attn_every, cfg.num_layers
        G, R = L // E, L % E
        return G, cfg.with_(num_layers=E + R), cfg.with_(num_layers=2 * E + R)
    if cfg.family == "encdec":
        G = cfg.num_layers
        assert cfg.encoder_layers == cfg.num_layers
        return G, cfg.with_(num_layers=1, encoder_layers=1), \
            cfg.with_(num_layers=2, encoder_layers=2)
    pat = len(layer_pattern(cfg))
    G = cfg.num_layers // pat
    return G, cfg.with_(num_layers=pat), cfg.with_(num_layers=2 * pat)


def _compile_costs(cfg, shape, mesh, strategy):
    from repro.distributed import stepfn
    jitted, structs = stepfn.make_step_for_shape(cfg, mesh, strategy, shape)
    with mesh, jax.transfer_guard("disallow"):
        compiled = jitted.lower(*structs).compile()
    cost = _cost_dict(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": {k: float(v) for k, v in coll.items()},
            "coll_total": float(sum(coll.values()))}


def roofline_pair(arch: str, shape_name: str, *,
                  strategy: Optional[str] = None,
                  multi_pod: bool = False, verbose: bool = True) -> Dict:
    """Exact per-device roofline cost terms for (arch x shape) via the
    G=1/G=2 extrapolation above.  Single-pod by default (per the brief)."""
    from repro.configs import get_config, default_strategy
    from repro.configs.base import SHAPES, shape_skips
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skips(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": skip}
    strategy = strategy or default_strategy(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    G, cfg1, cfg2 = _group_counts(cfg)
    cfg1 = cfg1.with_(scan_layers=False, attn_q_chunk=0)
    cfg2 = cfg2.with_(scan_layers=False, attn_q_chunk=0)
    t0 = time.time()
    c1 = _compile_costs(cfg1, shape, mesh, strategy)
    c2 = _compile_costs(cfg2, shape, mesh, strategy)

    def extrap(a, b):
        return a + (G - 1) * (b - a)

    coll = {k: extrap(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "strategy": strategy, "multi_pod": multi_pod,
        "chips": int(np.prod(list(mesh.shape.values()))),
        "groups": G, "compile_s": round(time.time() - t0, 1),
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes_accessed": extrap(c1["bytes"], c2["bytes"]),
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "collective_bytes_total": int(sum(coll.values())),
    }
    if verbose:
        print(f"[roofline {arch} x {shape_name} | {strategy}] "
              f"G={G} compile {rec['compile_s']}s  "
              f"flops/dev {rec['flops']:.3e}  bytes/dev "
              f"{rec['bytes_accessed']:.3e}  coll/dev "
              f"{rec['collective_bytes_total']:.3e}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (see repro/configs)")
    ap.add_argument("--shape", help="input shape name",
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) pair")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 = 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run each pair on single-pod AND multi-pod meshes")
    ap.add_argument("--strategy", choices=["dp", "dp_tp", "fsdp_tp"])
    ap.add_argument("--unrolled", action="store_true",
                    help="unroll layer loops for exact cost accounting "
                         "(roofline mode)")
    ap.add_argument("--json", help="append JSONL records to this path")
    args = ap.parse_args(argv)

    from repro.configs import ARCHS
    from repro.configs.base import SHAPES

    pairs = []
    archs = ARCHS if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    records, failures = [], []
    for arch, shape, mp in pairs:
        try:
            rec = dryrun_pair(arch, shape, multi_pod=mp,
                              strategy=args.strategy, unrolled=args.unrolled)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "fail", "error": f"{type(e).__name__}: {e}"}
            failures.append(rec)
        records.append(rec)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")

    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skip" for r in records)
    print(f"\ndry-run: {ok} ok, {skip} skip, {len(failures)} FAIL "
          f"of {len(records)}")
    for f_ in failures:
        print("  FAIL:", f_["arch"], f_["shape"],
              "multi_pod" if f_["multi_pod"] else "", f_["error"][:200])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
