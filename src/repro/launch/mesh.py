"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
models the pruned inter-pod link (the paper's 4:1 inter-island OmniPath
pruning has the same shape: cheap intra-island, scarce inter-island).

Defined as FUNCTIONS so importing this module never touches jax device
state; only launch/dryrun.py (which sets XLA_FLAGS first) builds the 512-
device host mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:                                   # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are implicitly Auto
    AxisType = None


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_mesh = make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None):
    """Mesh over whatever host devices exist (smoke tests / examples)."""
    n = data or len(jax.devices())
    return _mesh((n,), ("data",))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
