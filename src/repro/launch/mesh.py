"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
models the pruned inter-pod link (the paper's 4:1 inter-island OmniPath
pruning has the same shape: cheap intra-island, scarce inter-island).

Defined as FUNCTIONS so importing this module never touches jax device
state; only launch/dryrun.py (which sets XLA_FLAGS first) builds the 512-
device host mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: Optional[int] = None):
    """Mesh over whatever host devices exist (smoke tests / examples)."""
    n = data or len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
