"""Slurm submission-script generation (paper §IV-B/C command lines).

Renders sbatch scripts whose payload is the paper's exact launch pattern:

  single node (OpenMP inside the capsule):
      ch-run <image> -- python <script>
  multi node (hybrid MPI x OpenMP, one rank per node, 2 threads/core):
      mpiexec -n $SLURM_NTASKS -ppn 1 ch-run <image> -- python <script>
"""
from __future__ import annotations

import shlex
from typing import Dict, Optional

_TEMPLATE = """#!/bin/bash
#SBATCH --job-name={job_name}
#SBATCH --nodes={nodes}
#SBATCH --ntasks-per-node={ranks_per_node}
#SBATCH --cpus-per-task={threads_per_rank}
#SBATCH --time={walltime}
#SBATCH --partition={partition}
#SBATCH --export=NONE
# SuperMUC-NG: no internet on login/compute nodes; image must already be
# staged (ch-tar2dir) under node-local storage.

module load slurm_setup
export OMP_NUM_THREADS={omp_threads}
export KMP_AFFINITY=granularity=fine,compact
export KMP_BLOCKTIME=1
{extra_env}
{launch_line}
"""


def render_script(job_name: str, image_dir: str, entrypoint: str,
                  nodes: int = 1, ranks_per_node: int = 1,
                  threads_per_rank: int = 96, walltime: str = "08:00:00",
                  partition: str = "general", script: str = "train.py",
                  env: Optional[Dict[str, str]] = None) -> str:
    total_ranks = nodes * ranks_per_node
    if nodes == 1 and ranks_per_node == 1:
        # paper §IV-B: single node, OpenMP parallelism inside the capsule
        launch = f"ch-run {image_dir} -- {entrypoint} {script}"
    else:
        # paper §IV-C: hybrid MPI x OpenMP, one rank per node
        launch = (f"mpiexec -n {total_ranks} -ppn {ranks_per_node} "
                  f"ch-run {image_dir} -- {entrypoint} {script}")
    # values are shell-quoted (spool paths and JSON blobs carry spaces and
    # quotes); OMP threads clamp to >=1 — hyperthread halving of a single
    # CPU rank must not render OMP_NUM_THREADS=0
    extra = "\n".join(f"export {k}={shlex.quote(str(v))}"
                      for k, v in (env or {}).items())
    return _TEMPLATE.format(
        job_name=job_name, nodes=nodes, ranks_per_node=ranks_per_node,
        threads_per_rank=threads_per_rank, walltime=walltime,
        partition=partition, omp_threads=max(1, threads_per_rank // 2),
        extra_env=extra, launch_line=launch)
