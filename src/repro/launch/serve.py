"""Production serving launcher (in-capsule entrypoint).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models import transformer as T
    from repro.serving import Request, SamplingParams, ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve launcher targets decoder LMs")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_seq_len=args.max_seq_len,
                           max_slots=args.requests)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)),
                                 dtype=np.int32),
                    SamplingParams(max_new_tokens=args.max_new,
                                   greedy=args.greedy))
            for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: {o.tolist()}")
    print(f"{n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
