"""Production serving launcher (in-capsule entrypoint).

Routes requests through the continuous-batching scheduler: admission
queue -> per-slot prefill -> batched decode with per-request sampling ->
early exit on each request's own ``max_new_tokens`` / EOS.  Prints
per-request outputs plus TTFT / throughput telemetry, and can fan out
over multiple engine replicas (``--replicas``, each conceptually one
``ch-run`` capsule) behind the prefix-affine, load-balanced gateway.
``--prefix-cache-blocks N`` (default on) gives each replica an N-block
prefix store + radix index; ``--shared-prefix K`` makes every request
open with the same K synthetic tokens to exercise it.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --requests 8 --max-new 16 --shared-prefix 64

Add ``--metrics-json PATH`` to export the scheduler telemetry for the
benchmark harness, ``--metrics-out PATH`` for just the gateway-merged
totals summary, and ``--trace-out BASE`` to enable request-lifecycle
tracing and write ``BASE.jsonl`` (merged event log) plus
``BASE.chrome.json`` (Perfetto / chrome://tracing) at end of run;
``--trace-buffer-events`` sizes the per-replica ring buffer.

``--fabric {local,mock}`` promotes the fleet across process
boundaries: replicas become fabric workers (real subprocesses, or
deterministic in-process mocks) launched through a
``SchedulerBackend`` and driven over the shared-filesystem mailbox —
the same gateway, health ladder, and salvage machinery, with the
model rebuilt bit-identically in each worker from the declarative
spec.  ``--spool DIR`` picks the spool directory; ``--trace-out``
then merges gateway- and worker-side events into one fleet trace
(``scripts/trace_report.py --fleet``).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--max-slots", type=int, default=4,
                    help="continuous-batching slots per replica")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--fabric", choices=("local", "mock"), default=None,
                    help="launch replicas as fabric workers behind the "
                         "shared-filesystem mailbox instead of in-process "
                         "engines: 'local' = real subprocess workers "
                         "(LocalProcessBackend), 'mock' = deterministic "
                         "in-process workers (MockBackend); requires "
                         "--smoke — workers rebuild bit-identical weights "
                         "from the declarative smoke spec")
    ap.add_argument("--spool", default=None, metavar="DIR",
                    help="fabric spool directory "
                         "(default: results/fabric-spool)")
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--greedy-tie-eps", type=float, default=1e-2,
                    help="deterministic greedy tie break: pick the "
                         "lowest token id within eps of the max logit, "
                         "making argmax layout-stable under paged/dense "
                         "summation-order noise (on by default; pass 0 "
                         "to opt out and restore raw argmax)")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--metrics-json", default=None,
                    help="export full per-replica + merged telemetry JSON")
    ap.add_argument("--metrics-out", default=None,
                    help="export only the gateway-merged totals summary "
                         "JSON at end of run")
    ap.add_argument("--trace-out", default=None,
                    help="enable request-lifecycle tracing; writes "
                         "PATH.jsonl (merged events) + PATH.chrome.json "
                         "(Perfetto) at end of run")
    ap.add_argument("--trace-buffer-events", type=int, default=None,
                    help="per-replica trace ring-buffer depth "
                         "(default 65536; oldest events drop first)")
    ap.add_argument("--paged", action="store_true",
                    help="paged attention: block-resident KV gathered "
                         "through block tables (Pallas kernel)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (paged only; below "
                         "worst case = memory oversubscription)")
    ap.add_argument("--prefill-batch", type=int, default=4,
                    help="max co-admitted prompts per scheduler round "
                         "(batched multi-slot prefill; 1 = one-at-a-time)")
    ap.add_argument("--prefill-token-budget", type=int, default=None,
                    help="max executed prefill token positions per "
                         "scheduler step (SplitFuse-style interleaving: "
                         "bounds decode latency jitter under admission "
                         "bursts; default: unbudgeted wave-at-once)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=64,
                    help="per-replica prefix-store KV blocks (0 disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="open every prompt with this many shared tokens")
    ap.add_argument("--tenant", action="append", default=None,
                    metavar="NAME",
                    help="tenant label(s); repeat or comma-separate — "
                         "requests are assigned round-robin and get "
                         "per-tenant SLO percentiles (default: 'default')")
    ap.add_argument("--slo-config", default=None, metavar="PATH",
                    help="JSON SLO policy file: {\"default\": {...}, "
                         "\"tenants\": {name: {...}}} with thresholds "
                         "like ttft_p95_ms / gap_p95_ms; breaches land "
                         "in the trace as slo_breach events")
    ap.add_argument("--profile", action="store_true",
                    help="device-accurate step-phase timing "
                         "(block_until_ready-bracketed) + paged-kernel "
                         "cost/roofline profiles + recompile telemetry")
    ap.add_argument("--metrics-interval-steps", type=int, default=None,
                    metavar="N",
                    help="with --metrics-out: atomically re-write the "
                         "totals snapshot every N scheduler steps, so a "
                         "killed capsule leaves a readable last snapshot")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models import transformer as T
    from repro.serving import (ReplicaGateway, Request, SamplingParams,
                               ServingEngine, SLOConfig, atomic_write_json)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve launcher targets decoder LMs")
    tenants = [t for arg in (args.tenant or ["default"])
               for t in arg.split(",") if t]
    slo_config = (SLOConfig.from_json(args.slo_config)
                  if args.slo_config else None)
    fabric_backend = None
    spool = None
    if args.fabric:
        if not args.smoke:
            raise SystemExit("--fabric requires --smoke: workers rebuild "
                             "bit-identical weights from the declarative "
                             "smoke-config spec")
        if args.profile or args.slo_config:
            raise SystemExit("--fabric replicas live in other processes; "
                             "--profile / --slo-config introspection is "
                             "in-process only")
        from repro.serving import (LocalProcessBackend, MockBackend,
                                   collect_fabric_traces,
                                   launch_fabric_replicas, shutdown_fabric)
        backend_cls = {"local": LocalProcessBackend, "mock": MockBackend}
        fabric_backend = backend_cls[args.fabric]()
        spool = Path(args.spool or "results/fabric-spool")
        model_spec = {"config": args.arch, "seed": 0,
                      "engine": {"max_seq_len": args.max_seq_len,
                                 "max_slots": args.max_slots,
                                 "prefill_batch": args.prefill_batch,
                                 "greedy_tie_eps": args.greedy_tie_eps}}
        gateway = launch_fabric_replicas(
            args.replicas, fabric_backend, spool, model_spec=model_spec,
            tracing=True)
        print(f"run config: arch={cfg.name} replicas={args.replicas} "
              f"fabric={args.fabric} spool={spool} "
              f"max_slots={args.max_slots} max_seq_len={args.max_seq_len} "
              f"prefill_batch={args.prefill_batch}")
        for rep in gateway.replicas:
            print(f"fabric replica {rep.name}: {rep.capsule['backend']} "
                  f"job {rep.capsule['job_id']} "
                  f"(partition {rep.capsule['partition']})")
    else:
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        engines = [ServingEngine(cfg, params,
                                 max_seq_len=args.max_seq_len,
                                 max_slots=args.max_slots, rng_seed=r,
                                 prefix_cache_blocks=args.prefix_cache_blocks,
                                 paged=args.paged,
                                 num_blocks=args.num_blocks,
                                 prefill_batch=args.prefill_batch,
                                 greedy_tie_eps=args.greedy_tie_eps)
                   for r in range(args.replicas)]
        gateway = ReplicaGateway.from_engines(
            engines, prefill_token_budget=args.prefill_token_budget,
            tracing=args.trace_out is not None,
            trace_buffer_events=args.trace_buffer_events,
            slo_config=slo_config, profile=args.profile)
        print(f"run config: arch={cfg.name} replicas={args.replicas} "
              f"max_slots={args.max_slots} max_seq_len={args.max_seq_len} "
              f"paged={args.paged} num_blocks={args.num_blocks} "
              f"prefill_batch={engines[0].prefill_batch} "
              f"prefill_chunk={engines[0].prefill_chunk} "
              f"prefill_token_budget={args.prefill_token_budget} "
              f"prefix_cache_blocks={args.prefix_cache_blocks}")

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix,
                          dtype=np.int32)
    handles = [gateway.submit(Request(
        np.concatenate([shared,
                        rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 12)),
                                     dtype=np.int32)]),
        SamplingParams(max_new_tokens=args.max_new, greedy=args.greedy,
                       temperature=args.temperature),
        tenant=tenants[i % len(tenants)]))
        for i in range(args.requests)]
    # drain manually so periodic snapshots can flush mid-run: a killed
    # capsule then leaves the last atomic snapshot, not nothing
    gateway.draining = True
    for rep in gateway.replicas:
        rep.scheduler.draining = True
    steps = 0
    while gateway.has_work:
        gateway.step()
        steps += 1
        if (args.metrics_out and args.metrics_interval_steps
                and steps % args.metrics_interval_steps == 0):
            atomic_write_json(args.metrics_out,
                              gateway.stats()["totals"])

    for i, h in enumerate(handles):
        rep = gateway.replicas[h[0]]
        print(f"req {i} [{rep.name}]: {gateway.result(h).tolist()}")
    stats = gateway.stats()
    tot = stats["totals"]
    print(f"{tot['total_new_tokens']} tokens over "
          f"{tot['requests_completed']} requests on "
          f"{tot['replicas']} replica(s): "
          f"{tot['tokens_per_s']:.1f} tok/s, "
          f"ttft p95 {tot['ttft_ms_p95']:.1f} ms, "
          f"latency p95 {tot['latency_ms_p95']:.1f} ms, "
          f"slot occupancy {tot['slot_occupancy']:.2f}")
    dg = tot.get("decode_gap_ms", {})
    if dg.get("count"):
        print(f"decode jitter: inter-token gap p50 {dg['p50']:.2f} ms, "
              f"p95 {dg['p95']:.2f} ms, max {dg['max']:.2f} ms "
              f"over {dg['count']} gaps")
    pc = tot.get("prefix_cache", {})
    if pc.get("hits", 0) or pc.get("misses", 0):
        print(f"prefix cache: hit rate {pc['hit_rate']:.2f}, "
              f"{pc['cached_tokens_served']}/{pc['prompt_tokens']} prompt "
              f"tokens served from cache, {pc['evictions']} evictions")
    if len(tenants) > 1 or tenants != ["default"]:
        for name, ts in sorted(tot.get("tenants", {}).items()):
            print(f"tenant {name}: {ts['requests_completed']} requests, "
                  f"{ts['tokens_per_s']:.1f} tok/s, "
                  f"ttft p95 {ts['ttft_ms']['p95']:.1f} ms, "
                  f"gap p95 {ts['decode_gap_ms']['p95']:.2f} ms, "
                  f"queue wait p95 {ts['queue_wait_ms']['p95']:.2f} ms")
    if slo_config is not None:
        for rep in gateway.replicas:
            mon = rep.scheduler.tracer.slo
            s = mon.summary()
            print(f"SLO [{rep.name}]: {s['breaches']} breach(es), "
                  f"active: {s['active'] or 'none'}")
    if args.profile:
        for rep in gateway.replicas:
            ps = rep.scheduler.profiler.summary()
            phases = "  ".join(
                f"{p} p95 {ps[f'{p}_ms']['p95']:.2f}ms"
                for p in ("admit", "prefill", "decode", "sample"))
            print(f"profile [{rep.name}]: {ps['steps']} steps  {phases}")
            rs = rep.scheduler.engine.recompiles.summary()
            print(f"recompiles [{rep.name}]: {rs['compiles_total']} "
                  f"compilations, {rs['post_warm_recompiles']} post-warm, "
                  f"churning: {rs['churning'] or 'none'}")
        if args.paged:
            from repro.serving import profile_paged_kernels
            for name, prof in profile_paged_kernels(
                    gateway.replicas[0].scheduler.engine).items():
                print(f"kernel {name}: {prof['wall_ms_median']:.2f} ms, "
                      f"{prof['flops']:.3g} flops, "
                      f"{prof['achieved_tflops']:.3f} TFLOP/s "
                      f"({prof['fraction_of_peak_flops']:.1%} of peak), "
                      f"{prof['achieved_gbps']:.1f} GB/s "
                      f"({prof['fraction_of_peak_bw']:.1%} of HBM)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(stats, f, indent=2, sort_keys=True, default=str)
        print(f"metrics -> {args.metrics_json}")
    if args.metrics_out:
        out = atomic_write_json(args.metrics_out, stats["totals"])
        print(f"merged metrics summary -> {out}")
    if args.trace_out:
        if fabric_backend is not None:
            # worker streams land in the spool only at clean exit — stop
            # the fleet first, then merge gateway + worker events (no
            # chrome export: worker clocks are per-process monotonic)
            shutdown_fabric(gateway)
            n_ev = collect_fabric_traces(gateway, spool,
                                         f"{args.trace_out}.jsonl")
            print(f"fabric trace: {n_ev} merged events -> "
                  f"{args.trace_out}.jsonl (inspect: python "
                  f"scripts/trace_report.py --fleet "
                  f"{args.trace_out}.jsonl)")
        else:
            jsonl = gateway.export_trace_jsonl(f"{args.trace_out}.jsonl")
            chrome = gateway.export_chrome_trace(
                f"{args.trace_out}.chrome.json")
            n_ev = sum(tr.emitted_events for tr in gateway.tracers)
            n_drop = sum(tr.dropped_events for tr in gateway.tracers)
            print(f"trace: {n_ev} events ({n_drop} dropped by ring) -> "
                  f"{jsonl} + {chrome} "
                  f"(inspect: python scripts/trace_report.py {jsonl})")
    if fabric_backend is not None:
        shutdown_fabric(gateway)    # idempotent if the trace path ran


if __name__ == "__main__":
    main()
