"""Serving telemetry: TTFT, throughput, queue depth, slot occupancy.

Collected by the scheduler on every admission/decode/retire and exported
as JSON for the benchmark harness (``BENCH_serving.json``).  Latency
percentiles are computed over completed requests; gauge series (queue
depth, slot occupancy) are sampled once per scheduler step.  The clock is
injectable so tests can drive deterministic timings.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional


def _pct(xs: List[float], q: float) -> float:
    """Percentile by linear interpolation (numpy-free on purpose: callable
    from inside a capsule without pulling the model stack)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    f = (len(s) - 1) * q
    lo, hi = int(f), min(int(f) + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (f - lo)


class ServingMetrics:
    """Per-request timings + per-step gauges for one scheduler."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._submit: Dict[int, float] = {}
        self._first: Dict[int, float] = {}
        self._finish: Dict[int, float] = {}
        self._tokens: Dict[int, int] = {}
        self._reasons: Dict[int, str] = {}
        self.queue_depth: List[int] = []
        self.active_slots: List[int] = []
        self.max_slots: int = 0
        self.decode_steps: int = 0
        # prefix cache (zero everywhere when the cache is disabled)
        self.prefix_hits: int = 0
        self.prefix_misses: int = 0
        self.cached_tokens_served: int = 0
        self.prompt_tokens: int = 0
        self.prefix_evictions: int = 0
        # prefill work split: real prompt tokens vs what the compiled
        # chunk programs executed (chunk + batch-row padding included),
        # so co-admission padding overhead is visible, not silently
        # folded into the FLOPs proxy
        self.prefill_tokens_real: int = 0
        self.prefill_tokens_executed: int = 0
        # decode-step latency jitter: timestamp of every decode step;
        # the gaps between consecutive steps are the inter-token
        # latencies every running sequence experiences — the number
        # SplitFuse-style interleaving exists to bound
        self.decode_step_times: List[float] = []
        # prefill-budget accounting (interleaved scheduling): per
        # budgeted round, executed tokens vs the configured budget
        self.budget_rounds: int = 0
        self.budget_tokens_executed: int = 0
        self.budget_tokens_cap: int = 0

    # -- recording -----------------------------------------------------------

    def record_submit(self, rid: int) -> None:
        self._submit[rid] = self.clock()

    def record_first_token(self, rid: int) -> None:
        self._first[rid] = self.clock()

    def record_finish(self, rid: int, n_tokens: int, reason: str) -> None:
        self._finish[rid] = self.clock()
        self._tokens[rid] = n_tokens
        self._reasons[rid] = reason

    def record_prefix(self, cached_tokens: int, prompt_tokens: int) -> None:
        """One admission's prefix-cache outcome: how many of the prompt's
        tokens were served from the store instead of recomputed."""
        if cached_tokens > 0:
            self.prefix_hits += 1
        else:
            self.prefix_misses += 1
        self.cached_tokens_served += cached_tokens
        self.prompt_tokens += prompt_tokens

    def record_prefill_work(self, real: int, executed: int) -> None:
        """One admission batch's prefill accounting: ``real`` prompt
        tokens computed vs ``executed`` token positions the compiled
        programs ran (the difference is padding)."""
        self.prefill_tokens_real += real
        self.prefill_tokens_executed += executed

    def record_budget(self, executed: int, budget: int) -> None:
        """One budgeted prefill round: ``executed`` token positions ran
        against a cap of ``budget`` (utilization may exceed 1.0 — the
        first chunk round of a step always runs whole)."""
        self.budget_rounds += 1
        self.budget_tokens_executed += executed
        self.budget_tokens_cap += budget

    def sample_gauges(self, queue_depth: int, active: int,
                      max_slots: int) -> None:
        self.queue_depth.append(queue_depth)
        self.active_slots.append(active)
        self.max_slots = max_slots
        self.decode_steps += 1
        self.decode_step_times.append(self.clock())

    # -- reduction -----------------------------------------------------------

    def ttft_s(self) -> List[float]:
        return [self._first[r] - self._submit[r] for r in self._first
                if r in self._submit]

    def latency_s(self) -> List[float]:
        return [self._finish[r] - self._submit[r] for r in self._finish
                if r in self._submit]

    def decode_gaps_s(self) -> List[float]:
        """Inter-token gaps: time between consecutive decode steps.  An
        admission wave's prefill runs between two decode steps, so a
        wave-at-once stall shows up as one huge gap here."""
        t = self.decode_step_times
        return [b - a for a, b in zip(t, t[1:])]

    def summary(self) -> Dict[str, object]:
        ttft, lat = self.ttft_s(), self.latency_s()
        total_tokens = sum(self._tokens.values())
        span = ((max(self._finish.values()) - min(self._submit.values()))
                if self._finish and self._submit else 0.0)
        occ = (sum(self.active_slots) / (len(self.active_slots)
                                         * max(self.max_slots, 1))
               if self.active_slots else 0.0)
        reasons: Dict[str, int] = {}
        for r in self._reasons.values():
            reasons[r] = reasons.get(r, 0) + 1
        return {
            "requests_completed": len(self._finish),
            "total_new_tokens": total_tokens,
            "tokens_per_s": total_tokens / span if span > 0 else 0.0,
            "decode_steps": self.decode_steps,
            "ttft_ms": {"p50": _pct(ttft, 0.5) * 1e3,
                        "p95": _pct(ttft, 0.95) * 1e3,
                        "mean": (sum(ttft) / len(ttft) * 1e3
                                 if ttft else 0.0)},
            "latency_ms": {"p50": _pct(lat, 0.5) * 1e3,
                           "p95": _pct(lat, 0.95) * 1e3},
            "queue_depth": {"mean": (sum(self.queue_depth)
                                     / len(self.queue_depth)
                                     if self.queue_depth else 0.0),
                            "peak": max(self.queue_depth, default=0)},
            "slot_occupancy": occ,
            "finish_reasons": reasons,
            "prefill_tokens": {
                "real": self.prefill_tokens_real,
                "executed": self.prefill_tokens_executed,
                "padding": (self.prefill_tokens_executed
                            - self.prefill_tokens_real),
                "padding_fraction": (
                    (self.prefill_tokens_executed - self.prefill_tokens_real)
                    / max(self.prefill_tokens_executed, 1)),
            },
            "decode_gap_ms": self._decode_gap_summary(),
            "prefill_budget": {
                "rounds": self.budget_rounds,
                "tokens_executed": self.budget_tokens_executed,
                "utilization": (self.budget_tokens_executed
                                / max(self.budget_tokens_cap, 1)),
            },
            "prefix_cache": {
                "hits": self.prefix_hits,
                "misses": self.prefix_misses,
                "hit_rate": (self.prefix_hits
                             / max(self.prefix_hits + self.prefix_misses, 1)),
                "cached_tokens_served": self.cached_tokens_served,
                "prompt_tokens": self.prompt_tokens,
                "cached_token_fraction": (self.cached_tokens_served
                                          / max(self.prompt_tokens, 1)),
                "evictions": self.prefix_evictions,
            },
        }

    def _decode_gap_summary(self) -> Dict[str, float]:
        gaps = self.decode_gaps_s()
        return {
            "p50": _pct(gaps, 0.5) * 1e3,
            "p95": _pct(gaps, 0.95) * 1e3,
            "max": max(gaps, default=0.0) * 1e3,
            "mean": sum(gaps) / len(gaps) * 1e3 if gaps else 0.0,
            "count": len(gaps),
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2,
                          sort_keys=True)

    def export(self, path, **extra) -> Path:
        path = Path(path)
        path.write_text(self.to_json(**extra) + "\n")
        return path


def merge_summaries(summaries: List[Dict[str, object]]) -> Dict[str, object]:
    """Aggregate per-replica summaries into gateway-level totals.

    Edge cases are contractual: an empty list returns the explicit
    ``{"replicas": 0}`` sentinel (not ``{}``, not an exception), and a
    single-replica list passes through its numbers unchanged — partial
    summaries (an idle replica, a hand-built dict missing sections)
    merge with zero defaults instead of raising or emitting NaN."""
    if not summaries:
        return {"replicas": 0}
    total_tokens = sum(s.get("total_new_tokens", 0) for s in summaries)
    pc = [s["prefix_cache"] for s in summaries if "prefix_cache" in s]
    hits = sum(p["hits"] for p in pc)
    misses = sum(p["misses"] for p in pc)
    cached = sum(p["cached_tokens_served"] for p in pc)
    prompt = sum(p["prompt_tokens"] for p in pc)
    pf = [s["prefill_tokens"] for s in summaries if "prefill_tokens" in s]
    pf_real = sum(p["real"] for p in pf)
    pf_exec = sum(p["executed"] for p in pf)
    # jitter percentiles: only replicas that actually decoded carry
    # gaps.  A replica with zero decode steps (or one step — no gap)
    # reports count 0 and must contribute NOTHING: folding its 0.0
    # percentiles into a mean (or counting it in the denominator) would
    # dilute the fleet's jitter numbers — the double-counting bug class
    # this merge had with prefix stats.  Percentile merge is the
    # conservative cross-replica bound (max); the mean is weighted by
    # each replica's gap count.
    dg = [s["decode_gap_ms"] for s in summaries
          if s.get("decode_gap_ms", {}).get("count", 0) > 0]
    n_gaps = sum(d["count"] for d in dg)
    decode_gap = {
        "p50": max((d["p50"] for d in dg), default=0.0),
        "p95": max((d["p95"] for d in dg), default=0.0),
        "max": max((d["max"] for d in dg), default=0.0),
        "mean": (sum(d["mean"] * d["count"] for d in dg) / n_gaps
                 if n_gaps else 0.0),
        "count": n_gaps,
    }
    # budget utilization weighted by budgeted rounds, same rationale
    pb = [s["prefill_budget"] for s in summaries
          if s.get("prefill_budget", {}).get("rounds", 0) > 0]
    pb_rounds = sum(b["rounds"] for b in pb)
    pb_exec = sum(b["tokens_executed"] for b in pb)
    pb_util = (sum(b["utilization"] * b["rounds"] for b in pb) / pb_rounds
               if pb_rounds else 0.0)
    return {
        "decode_gap_ms": decode_gap,
        "prefill_budget": {"rounds": pb_rounds,
                           "tokens_executed": pb_exec,
                           "utilization": pb_util},
        "prefill_tokens": {
            "real": pf_real, "executed": pf_exec,
            "padding": pf_exec - pf_real,
            "padding_fraction": (pf_exec - pf_real) / max(pf_exec, 1),
        },
        "prefix_cache": {
            "hits": hits, "misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
            "cached_tokens_served": cached,
            "prompt_tokens": prompt,
            "cached_token_fraction": cached / max(prompt, 1),
            "evictions": sum(p["evictions"] for p in pc),
        },
        "replicas": len(summaries),
        "requests_completed": sum(s.get("requests_completed", 0)
                                  for s in summaries),
        "total_new_tokens": total_tokens,
        "tokens_per_s": sum(s.get("tokens_per_s", 0.0) for s in summaries),
        "decode_steps": sum(s.get("decode_steps", 0) for s in summaries),
        "ttft_ms_p95": max((s.get("ttft_ms", {}).get("p95", 0.0)
                            for s in summaries), default=0.0),
        "latency_ms_p95": max((s.get("latency_ms", {}).get("p95", 0.0)
                               for s in summaries), default=0.0),
        "slot_occupancy": (sum(s.get("slot_occupancy", 0.0)
                               for s in summaries) / len(summaries)),
    }
