"""Serving telemetry: TTFT, throughput, queue depth, slot occupancy.

Collected by the scheduler on every admission/decode/retire and exported
as JSON for the benchmark harness (``BENCH_serving.json``).  Latency
percentiles are computed over completed requests; gauge series (queue
depth, slot occupancy) are sampled once per scheduler step.  The clock is
injectable so tests can drive deterministic timings.

Memory is bounded for month-long deployments (the source paper's
deploy-and-run setting): per-request timing entries are kept for every
*in-flight* request plus the most recent ``sample_cap`` finished ones
(older finished entries are evicted FIFO), and every percentile series
lives in a :class:`~repro.serving.slo.SlidingWindow` ring.  Totals —
request counts, token counts, finish reasons, gauge means/peaks — are
running scalars and stay exact forever.  Below the cap nothing is ever
evicted, so small runs (every test, every benchmark) see byte-identical
numbers to the unbounded implementation.

Per-tenant rollups: each request carries a tenant label (threaded from
``Request.tenant`` through ``Tracer.submit``); TTFT, inter-token gap and
queue-wait land in that tenant's :class:`~repro.serving.slo.TenantStats`
windows, surfaced under ``summary()["tenants"]`` and merged across
replicas by :func:`merge_summaries`.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from pathlib import Path
from typing import Dict, Iterable, List, Optional

from repro.serving.slo import (SlidingWindow, TenantStats,
                               merge_tenant_summaries)

DEFAULT_SAMPLE_CAP = 4096


def _pct(xs: List[float], q: float) -> float:
    """Percentile by linear interpolation (numpy-free on purpose: callable
    from inside a capsule without pulling the model stack)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    f = (len(s) - 1) * q
    lo, hi = int(f), min(int(f) + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (f - lo)


def atomic_write_json(path, obj: dict) -> Path:
    """Write JSON via a same-directory temp file + ``os.replace`` so a
    capsule killed mid-write leaves the previous snapshot readable, never
    a truncated file (``--metrics-interval-steps`` relies on this)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(obj, indent=2, sort_keys=True,
                              default=str) + "\n")
    os.replace(tmp, path)
    return path


class ServingMetrics:
    """Per-request timings + per-step gauges for one scheduler."""

    def __init__(self, clock=time.perf_counter,
                 sample_cap: int = DEFAULT_SAMPLE_CAP,
                 tenant_window: int = 512):
        if sample_cap <= 0:
            raise ValueError(f"sample_cap must be positive, got {sample_cap}")
        self.clock = clock
        self.sample_cap = sample_cap
        self.tenant_window = tenant_window
        # per-request timing dicts: all in-flight rids + the most recent
        # ``sample_cap`` finished ones (FIFO eviction of older finished
        # entries — callers may index recently finished rids directly,
        # e.g. examples/serve_lm.py computes per-request TTFT post-run)
        self._submit: Dict[int, float] = {}
        self._first: Dict[int, float] = {}
        self._finish: Dict[int, float] = {}
        self._tokens: Dict[int, int] = {}
        self._reasons: Dict[int, str] = {}
        self._finished_order: deque = deque()
        # running totals (exact, never evicted)
        self.requests_submitted = 0
        self.requests_completed = 0
        self.total_new_tokens = 0
        self.finish_reason_counts: Dict[str, int] = {}
        # fault tolerance: failover re-submissions land here instead of
        # requests_submitted (one logical submit per request), terminal
        # typed failures and load-shed rejections are separate outcomes
        self.requests_retried = 0
        self.requests_failed = 0
        self.requests_shed = 0
        self.failure_reason_counts: Dict[str, int] = {}
        self._first_submit_ts: Optional[float] = None
        self._last_finish_ts: Optional[float] = None
        # gauges: running aggregates (exact) — sampled on decode steps
        self.max_slots: int = 0
        self.decode_steps: int = 0
        self._queue_sum = 0
        self._queue_samples = 0
        self._queue_peak = 0
        self._occ_sum = 0
        # prefix cache (zero everywhere when the cache is disabled)
        self.prefix_hits: int = 0
        self.prefix_misses: int = 0
        self.cached_tokens_served: int = 0
        self.prompt_tokens: int = 0
        self.prefix_evictions: int = 0
        # prefill work split: real prompt tokens vs what the compiled
        # chunk programs executed (chunk + batch-row padding included),
        # so co-admission padding overhead is visible, not silently
        # folded into the FLOPs proxy
        self.prefill_tokens_real: int = 0
        self.prefill_tokens_executed: int = 0
        # decode-step latency jitter: gaps between consecutive decode
        # steps — the inter-token latencies every running sequence
        # experiences, the number SplitFuse-style interleaving exists to
        # bound.  Stored in seconds; count/mean/max are all-time.
        self.decode_gaps = SlidingWindow(sample_cap)
        self._last_step_ts: Optional[float] = None
        # queue wait (submit -> first admit), ms
        self.queue_wait_ms = SlidingWindow(sample_cap)
        # prefill-budget accounting (interleaved scheduling): per
        # budgeted round, executed tokens vs the configured budget
        self.budget_rounds: int = 0
        self.budget_tokens_executed: int = 0
        self.budget_tokens_cap: int = 0
        # per-tenant rollups
        self.tenants: Dict[str, TenantStats] = {}
        self._tenant_of: Dict[int, str] = {}      # in-flight rids only
        self._admitted: set = set()               # rids past first admit
        self._last_tok_ts: Dict[int, float] = {}  # in-flight decode rows

    # -- recording -----------------------------------------------------------

    def _tenant(self, tenant: str) -> TenantStats:
        ts = self.tenants.get(tenant)
        if ts is None:
            ts = self.tenants[tenant] = TenantStats(self.tenant_window)
        return ts

    def record_submit(self, rid: int, tenant: str = "default",
                      retry: bool = False) -> None:
        """``retry=True`` is a failover re-submission of a request this
        *fleet* already counted: it gets a fresh timing entry (its queue
        wait and serving span here are real) but increments
        ``requests_retried`` instead of the logical submit counters, so
        merged summaries count one submit per request."""
        now = self.clock()
        self._submit[rid] = now
        if retry:
            self.requests_retried += 1
        else:
            self.requests_submitted += 1
            self._tenant(tenant).submitted += 1
        if self._first_submit_ts is None or now < self._first_submit_ts:
            self._first_submit_ts = now
        self._tenant_of[rid] = tenant
        t = self._tenant(tenant)
        if t.first_submit_ts is None or now < t.first_submit_ts:
            t.first_submit_ts = now

    def record_admit(self, rid: int) -> None:
        """First admission of ``rid``: queue wait = submit -> now.  A
        re-admit after preemption is not a queue wait and is ignored."""
        if rid in self._admitted or rid not in self._submit:
            return
        self._admitted.add(rid)
        wait_ms = (self.clock() - self._submit[rid]) * 1e3
        self.queue_wait_ms.add(wait_ms)
        tenant = self._tenant_of.get(rid)
        if tenant is not None:
            self._tenant(tenant).queue_wait_ms.add(wait_ms)

    def record_first_token(self, rid: int) -> None:
        now = self.clock()
        self._first[rid] = now
        self._last_tok_ts[rid] = now
        sub = self._submit.get(rid)
        tenant = self._tenant_of.get(rid)
        if sub is not None and tenant is not None:
            self._tenant(tenant).ttft_ms.add((now - sub) * 1e3)

    def record_decode_tokens(self, rids: Iterable[int]) -> None:
        """One decode step emitted a token for each of ``rids``: record
        the per-request inter-token gap into its tenant's window."""
        now = self.clock()
        for rid in rids:
            last = self._last_tok_ts.get(rid)
            self._last_tok_ts[rid] = now
            if last is None:
                continue
            tenant = self._tenant_of.get(rid)
            if tenant is not None:
                self._tenant(tenant).gap_ms.add((now - last) * 1e3)

    def record_finish(self, rid: int, n_tokens: int, reason: str) -> None:
        now = self.clock()
        first_finish = rid not in self._finish
        self._finish[rid] = now
        self._tokens[rid] = n_tokens
        self._reasons[rid] = reason
        if not first_finish:
            return
        self._finished_order.append(rid)
        self.requests_completed += 1
        self.total_new_tokens += n_tokens
        self.finish_reason_counts[reason] = (
            self.finish_reason_counts.get(reason, 0) + 1)
        if self._last_finish_ts is None or now > self._last_finish_ts:
            self._last_finish_ts = now
        tenant = self._tenant_of.pop(rid, None)
        if tenant is not None:
            t = self._tenant(tenant)
            t.completed += 1
            t.new_tokens += n_tokens
            if t.last_finish_ts is None or now > t.last_finish_ts:
                t.last_finish_ts = now
        self._admitted.discard(rid)
        self._last_tok_ts.pop(rid, None)
        while len(self._finished_order) > self.sample_cap:
            old = self._finished_order.popleft()
            for d in (self._submit, self._first, self._finish,
                      self._tokens, self._reasons):
                d.pop(old, None)

    def record_failed(self, reason: str) -> None:
        """Terminal typed failure: retry budget exhausted or no replica
        left.  Failed requests never touch the completion counters or
        the latency percentiles — they are a separate outcome."""
        self.requests_failed += 1
        self.failure_reason_counts[reason] = (
            self.failure_reason_counts.get(reason, 0) + 1)

    def record_shed(self, tenant: str) -> None:
        """A submit was rejected (Overloaded) by the degradation
        ladder; the request never entered any queue."""
        self.requests_shed += 1
        self.failure_reason_counts[f"shed:{tenant}"] = (
            self.failure_reason_counts.get(f"shed:{tenant}", 0) + 1)

    def record_prefix(self, cached_tokens: int, prompt_tokens: int) -> None:
        """One admission's prefix-cache outcome: how many of the prompt's
        tokens were served from the store instead of recomputed."""
        if cached_tokens > 0:
            self.prefix_hits += 1
        else:
            self.prefix_misses += 1
        self.cached_tokens_served += cached_tokens
        self.prompt_tokens += prompt_tokens

    def record_prefill_work(self, real: int, executed: int) -> None:
        """One admission batch's prefill accounting: ``real`` prompt
        tokens computed vs ``executed`` token positions the compiled
        programs ran (the difference is padding)."""
        self.prefill_tokens_real += real
        self.prefill_tokens_executed += executed

    def record_budget(self, executed: int, budget: int) -> None:
        """One budgeted prefill round: ``executed`` token positions ran
        against a cap of ``budget`` (utilization may exceed 1.0 — the
        first chunk round of a step always runs whole)."""
        self.budget_rounds += 1
        self.budget_tokens_executed += executed
        self.budget_tokens_cap += budget

    def sample_gauges(self, queue_depth: int, active: int,
                      max_slots: int) -> None:
        self._queue_sum += queue_depth
        self._queue_samples += 1
        if queue_depth > self._queue_peak:
            self._queue_peak = queue_depth
        self._occ_sum += active
        self.max_slots = max_slots
        self.decode_steps += 1
        now = self.clock()
        if self._last_step_ts is not None:
            self.decode_gaps.add(now - self._last_step_ts)
        self._last_step_ts = now

    # -- reduction -----------------------------------------------------------

    def ttft_s(self) -> List[float]:
        return [self._first[r] - self._submit[r] for r in self._first
                if r in self._submit]

    def latency_s(self) -> List[float]:
        return [self._finish[r] - self._submit[r] for r in self._finish
                if r in self._submit]

    def decode_gaps_s(self) -> List[float]:
        """Inter-token gaps: time between consecutive decode steps (the
        windowed ring — all-time count/max live on ``decode_gaps``).  An
        admission wave's prefill runs between two decode steps, so a
        wave-at-once stall shows up as one huge gap here."""
        return list(self.decode_gaps.ring)

    def summary(self) -> Dict[str, object]:
        ttft, lat = self.ttft_s(), self.latency_s()
        span = ((self._last_finish_ts - self._first_submit_ts)
                if self._last_finish_ts is not None
                and self._first_submit_ts is not None else 0.0)
        occ = (self._occ_sum / (self._queue_samples
                                * max(self.max_slots, 1))
               if self._queue_samples else 0.0)
        return {
            "requests_completed": self.requests_completed,
            "requests_submitted": self.requests_submitted,
            "requests_retried": self.requests_retried,
            "requests_failed": self.requests_failed,
            "requests_shed": self.requests_shed,
            "failure_reasons": dict(self.failure_reason_counts),
            "total_new_tokens": self.total_new_tokens,
            "tokens_per_s": (self.total_new_tokens / span
                             if span > 0 else 0.0),
            "decode_steps": self.decode_steps,
            "ttft_ms": {"p50": _pct(ttft, 0.5) * 1e3,
                        "p95": _pct(ttft, 0.95) * 1e3,
                        "mean": (sum(ttft) / len(ttft) * 1e3
                                 if ttft else 0.0)},
            "latency_ms": {"p50": _pct(lat, 0.5) * 1e3,
                           "p95": _pct(lat, 0.95) * 1e3},
            "queue_depth": {"mean": (self._queue_sum / self._queue_samples
                                     if self._queue_samples else 0.0),
                            "peak": self._queue_peak},
            "queue_wait_ms": self.queue_wait_ms.summary(),
            "slot_occupancy": occ,
            "finish_reasons": dict(self.finish_reason_counts),
            "prefill_tokens": {
                "real": self.prefill_tokens_real,
                "executed": self.prefill_tokens_executed,
                "padding": (self.prefill_tokens_executed
                            - self.prefill_tokens_real),
                "padding_fraction": (
                    (self.prefill_tokens_executed - self.prefill_tokens_real)
                    / max(self.prefill_tokens_executed, 1)),
            },
            "decode_gap_ms": self._decode_gap_summary(),
            "prefill_budget": {
                "rounds": self.budget_rounds,
                "tokens_executed": self.budget_tokens_executed,
                "utilization": (self.budget_tokens_executed
                                / max(self.budget_tokens_cap, 1)),
            },
            "prefix_cache": {
                "hits": self.prefix_hits,
                "misses": self.prefix_misses,
                "hit_rate": (self.prefix_hits
                             / max(self.prefix_hits + self.prefix_misses, 1)),
                "cached_tokens_served": self.cached_tokens_served,
                "prompt_tokens": self.prompt_tokens,
                "cached_token_fraction": (self.cached_tokens_served
                                          / max(self.prompt_tokens, 1)),
                "evictions": self.prefix_evictions,
            },
            "tenants": {name: t.summary()
                        for name, t in sorted(self.tenants.items())},
        }

    def _decode_gap_summary(self) -> Dict[str, float]:
        g = self.decode_gaps
        return {
            "p50": g.percentile(0.5) * 1e3,
            "p95": g.percentile(0.95) * 1e3,
            "max": g.peak * 1e3,
            "mean": g.mean * 1e3 if g.count else 0.0,
            "count": g.count,
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2,
                          sort_keys=True)

    def export(self, path, **extra) -> Path:
        return atomic_write_json(path, {**self.summary(), **extra})


def merge_summaries(summaries: List[Dict[str, object]]) -> Dict[str, object]:
    """Aggregate per-replica summaries into gateway-level totals.

    Edge cases are contractual: an empty list returns the explicit
    ``{"replicas": 0}`` sentinel (not ``{}``, not an exception), and a
    single-replica list passes through its numbers unchanged — partial
    summaries (an idle replica, a hand-built dict missing sections)
    merge with zero defaults instead of raising or emitting NaN."""
    if not summaries:
        return {"replicas": 0}
    total_tokens = sum(s.get("total_new_tokens", 0) for s in summaries)
    pc = [s["prefix_cache"] for s in summaries if "prefix_cache" in s]
    hits = sum(p["hits"] for p in pc)
    misses = sum(p["misses"] for p in pc)
    cached = sum(p["cached_tokens_served"] for p in pc)
    prompt = sum(p["prompt_tokens"] for p in pc)
    pf = [s["prefill_tokens"] for s in summaries if "prefill_tokens" in s]
    pf_real = sum(p["real"] for p in pf)
    pf_exec = sum(p["executed"] for p in pf)
    # jitter percentiles: only replicas that actually decoded carry
    # gaps.  A replica with zero decode steps (or one step — no gap)
    # reports count 0 and must contribute NOTHING: folding its 0.0
    # percentiles into a mean (or counting it in the denominator) would
    # dilute the fleet's jitter numbers — the double-counting bug class
    # this merge had with prefix stats.  Percentile merge is the
    # conservative cross-replica bound (max); the mean is weighted by
    # each replica's gap count.
    dg = [s["decode_gap_ms"] for s in summaries
          if s.get("decode_gap_ms", {}).get("count", 0) > 0]
    n_gaps = sum(d["count"] for d in dg)
    decode_gap = {
        "p50": max((d["p50"] for d in dg), default=0.0),
        "p95": max((d["p95"] for d in dg), default=0.0),
        "max": max((d["max"] for d in dg), default=0.0),
        "mean": (sum(d["mean"] * d["count"] for d in dg) / n_gaps
                 if n_gaps else 0.0),
        "count": n_gaps,
    }
    # budget utilization weighted by budgeted rounds, same rationale
    pb = [s["prefill_budget"] for s in summaries
          if s.get("prefill_budget", {}).get("rounds", 0) > 0]
    pb_rounds = sum(b["rounds"] for b in pb)
    pb_exec = sum(b["tokens_executed"] for b in pb)
    pb_util = (sum(b["utilization"] * b["rounds"] for b in pb) / pb_rounds
               if pb_rounds else 0.0)
    return {
        "decode_gap_ms": decode_gap,
        "prefill_budget": {"rounds": pb_rounds,
                           "tokens_executed": pb_exec,
                           "utilization": pb_util},
        "prefill_tokens": {
            "real": pf_real, "executed": pf_exec,
            "padding": pf_exec - pf_real,
            "padding_fraction": (pf_exec - pf_real) / max(pf_exec, 1),
        },
        "prefix_cache": {
            "hits": hits, "misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
            "cached_tokens_served": cached,
            "prompt_tokens": prompt,
            "cached_token_fraction": cached / max(prompt, 1),
            "evictions": sum(p["evictions"] for p in pc),
        },
        "replicas": len(summaries),
        "requests_completed": sum(s.get("requests_completed", 0)
                                  for s in summaries),
        # fault-tolerance outcomes: a retried request contributed one
        # requests_submitted (on its first replica) and one retry per
        # re-route — summing keeps the one-logical-submit invariant
        "requests_submitted": sum(s.get("requests_submitted", 0)
                                  for s in summaries),
        "requests_retried": sum(s.get("requests_retried", 0)
                                for s in summaries),
        "requests_failed": sum(s.get("requests_failed", 0)
                               for s in summaries),
        "requests_shed": sum(s.get("requests_shed", 0) for s in summaries),
        "total_new_tokens": total_tokens,
        "tokens_per_s": sum(s.get("tokens_per_s", 0.0) for s in summaries),
        "decode_steps": sum(s.get("decode_steps", 0) for s in summaries),
        "ttft_ms_p95": max((s.get("ttft_ms", {}).get("p95", 0.0)
                            for s in summaries), default=0.0),
        "latency_ms_p95": max((s.get("latency_ms", {}).get("p95", 0.0)
                               for s in summaries), default=0.0),
        "slot_occupancy": (sum(s.get("slot_occupancy", 0.0)
                               for s in summaries) / len(summaries)),
        # per-tenant rollups: tenants union across replicas (disjoint
        # keys pass through); overlapping tenants merge window-wise —
        # zero-count windows (an idle or zero-decode replica) contribute
        # nothing, extending the jitter-dilution regression to tenants
        "tenants": merge_tenant_summaries(
            [s.get("tenants", {}) for s in summaries]),
    }
