"""Batched serving engine: slot-granular prefill/decode primitives.

The serving counterpart of the deployment story: the same capsule image
serves a model with continuously batched requests.  The engine owns the
pooled decode cache (a :class:`~repro.serving.kvcache.PagedKVCache` over
``max_slots`` sequences) and exposes the primitives the scheduler drives:

* ``prefill_into_slots`` — co-prefill a *batch* of prompts, one
  fixed-shape chunked program per round.  In paged mode every chunk's
  K/V is written **straight into pool blocks** through the slots' block
  tables (the Pallas paged-prefill kernel gathers the history back out),
  so paged prefill never allocates the transient dense ``max_seq_len``
  batch-1 stripe the old path scattered from.  Prompts are length-sorted
  into waves of ``prefill_batch`` rows so similar suffix lengths share
  rounds; rows whose prompt ran out ride along as ``q_len = 0`` padding
  the kernel skips at page granularity.  Per-row ``start_pos`` resumes
  from a cached prefix (block-to-block loads from the prefix store) and
  each row's last *real* token's logits are extracted for the first
  sample.  Dense mode serves the same interface through the original
  batch-1 ``lax.scan`` chunk replay (the correctness oracle).
* ``begin_prefill`` / ``advance_prefill`` / ``cancel_prefill`` — the
  *resumable* form of the same work, the substrate of SplitFuse-style
  prefill/decode interleaving.  ``begin_prefill`` claims slots (and
  prefix blocks) and registers one :class:`PrefillCursor` per prompt on
  the engine; ``advance_prefill`` runs chunk rounds against the
  in-flight cursors under a *token budget* (executed token positions,
  the FLOPs proxy) and returns the cursors that completed, each with
  its last real token's logits; cursors that did not finish stay parked
  on the engine — their prefill state (position cursor, and in dense
  mode the staging cache) persists **between scheduler steps**, so a
  decode step for every running sequence can run in between.
  ``cancel_prefill`` abandons a partially-prefilled slot (preemption).
* ``prefill_into_slot`` — single-prompt compatibility wrapper.
* ``decode_once`` — one token for every slot against the pooled cache;
  while cursors are in flight their slots' block-table rows are masked
  to the trash block, so the dummy decode rows of mid-prefill slots can
  never corrupt the KV the prefill already wrote;
  ``serve_step`` here is the exact program the decode dry-run shapes
  lower.  Logits stay **on device**; the host transfer is deferred to
  ``sample_tokens`` so each decode step costs one sync, not two.

Sampling is vectorized per slot (``sample_tokens``): each row gets its own
temperature / greedy flag, fixing the seed bug where ``requests[0].params``
was applied to the whole batch.  ``generate()`` survives as a thin
compatibility wrapper that routes through the continuous-batching
scheduler.

Telemetry: ``prefill_tokens`` counts real prompt tokens,
``prefill_tokens_executed`` counts every token position the compiled
prefill programs actually ran (chunk padding and dummy batch rows
included — the FLOPs proxy), and ``prefill_tokens_padding`` is their
difference.  ``transient_prefill_bytes`` records the peak size of any
batch-1 staging cache a prefill allocated: nonzero for the dense path,
**always zero in paged mode** — the assertion behind the no-dense-stripe
guarantee.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serving.kvcache import PagedKVCache


@dataclass
class SamplingParams:
    temperature: float = 1.0
    greedy: bool = False
    max_new_tokens: int = 32
    eos_token: Optional[int] = None      # early-exit on this token id


@dataclass
class Request:
    prompt: np.ndarray                       # (prompt_len,) int32
    params: SamplingParams = field(default_factory=SamplingParams)
    # enc-dec (whisper): precomputed frame embeddings (enc_seq, d_model);
    # the engine runs the encoder once at prefill
    encoder_input: Optional[np.ndarray] = None
    # SLO tenant label: threaded submit -> scheduler -> metrics so
    # mixed-SLA traffic gets per-tenant percentiles (serving/slo.py)
    tenant: str = "default"


@dataclass
class PrefillCursor:
    """Progress of one in-flight (resumable) prefill.

    ``tokens`` is the full target sequence, ``start_pos`` the
    prefix-cache resume offset, and ``pos`` the next position to
    execute: ``start_pos <= pos <= len(tokens)``.  ``seq`` is the
    begin-order stamp advance rounds are scheduled by (FIFO — no
    admission can be starved by a stream of later, shorter ones).
    ``last_logits`` is set (device-resident) once the row's last real
    token has run.  In dense mode ``dense_cache`` carries the batch-1
    staging cache across ``advance_prefill`` calls — the state that
    makes mid-prompt suspension possible; it materializes lazily at the
    cursor's first chunk (so co-admitted prompts waiting their turn
    hold no stripe) and ``prefix_blocks`` keeps the pinned block ids
    until then.  Paged mode needs neither: chunks land straight in pool
    blocks, which persist by construction."""
    slot: int
    tokens: np.ndarray
    start_pos: int
    pos: int
    seq: int = 0
    encoder_input: Optional[np.ndarray] = None
    prefix_blocks: Tuple[int, ...] = ()
    dense_cache: object = None
    enc1: object = None
    last_logits: object = None

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.pos

    @property
    def done(self) -> bool:
        return self.pos >= len(self.tokens)


def make_serve_step(cfg, *, long_context: bool = False):
    """serve_step(params, batch) -> (logits, new_cache); batch carries
    tokens (B,1), positions (B,), cache (and encoder_output / mrope)."""
    def serve_step(params, batch):
        return T.decode_step(params, cfg, batch, long_context=long_context)
    return serve_step


class ServingEngine:
    """Fixed-slot batched engine (continuous batching over ``max_slots``).

    ``prefix_cache_blocks > 0`` turns on the prefix-cache subsystem (see
    :mod:`repro.serving.prefix_cache`): the paged cache grows a prefix
    store of that many KV blocks and ``self.prefix_cache`` holds the
    radix index the scheduler probes at admission.  Families whose decode
    cache is not positional (SSM/hybrid state) or whose KV depends on
    more than the token ids (enc-dec) silently leave it disabled.

    ``paged=True`` switches the decode cache to physical block storage
    gathered through per-slot block tables by the Pallas paged-attention
    kernel; ``num_blocks`` then sizes the KV pool (default: worst case),
    and sizing it *below* ``max_slots * ceil(max_seq_len/block_size)``
    makes ``OutOfBlocks`` a real event the scheduler handles by deferring
    admissions and preempting decode — the memory-oversubscription mode
    that lets one replica serve more concurrent sequences than the dense
    layout at the same KV budget.  Requires a positional, non-int8
    attention cache (dense / MoE / VLM families).
    """

    def __init__(self, cfg, params, max_seq_len: int, max_slots: int = 8,
                 rng_seed: int = 0, kv_block_size: int = 16,
                 prefix_cache_blocks: int = 0, prefill_chunk: int = 16,
                 paged: bool = False, num_blocks: Optional[int] = None,
                 prefill_batch: int = 4, greedy_tie_eps: float = 1e-2):
        self.cfg = cfg
        self.params = params
        self.max_seq_len = max_seq_len
        self.max_slots = max_slots
        self.key = jax.random.PRNGKey(rng_seed)
        self.prefill_chunk = prefill_chunk
        # > 0 makes greedy argmax layout-deterministic: any token whose
        # logit is within eps of the max is eligible and the LOWEST id
        # wins, so the ~1e-3 page-order summation noise between the
        # paged and dense layouts can no longer flip a near-tie argmax.
        # On by default (1e-2) since the chaos/failover suites held
        # bit-identity with it armed across every fault schedule; pass
        # 0.0 to restore the historical raw-argmax outputs
        self.greedy_tie_eps = float(greedy_tie_eps)
        # rows per compiled paged-prefill program (co-admission width);
        # dense mode prefills serially whatever the batch size
        self.prefill_batch = max(1, min(prefill_batch, max_slots))
        self.paged = paged
        want_prefix = prefix_cache_blocks > 0
        self.kv = PagedKVCache(
            cfg, max_slots, max_seq_len, block_size=kv_block_size,
            prefix_blocks=(prefix_cache_blocks if want_prefix and
                           self._family_supports_prefix(cfg) else 0),
            num_blocks=num_blocks, paged=paged)
        self.prefix_cache = None
        if self.kv.prefix_pool is not None:
            from repro.serving.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(self.kv)
        self.decode_steps = 0                # accounting (tested)
        self.prefill_tokens = 0              # real tokens run through prefill
        self.prefill_tokens_executed = 0     # incl. padding (FLOPs proxy)
        self.prefill_tokens_padding = 0      # executed - real
        self.cached_prefix_tokens = 0        # tokens served from the store
        self.transient_prefill_bytes = 0     # peak batch-1 staging cache
        # bound by the scheduler that drives this engine (one tracer per
        # replica); None until then — engine-side trace emission is
        # guarded so direct primitive use stays untraced
        self.tracer = None
        # deterministic fault injection (serving/faults.py): bound by
        # the scheduler alongside the tracer; None = no hooks fire
        self.fault_injector = None
        # jit recompilation telemetry: each compiled program's argument
        # shape signature is reported per call; post-warm novelty is the
        # variable-batch shape-churn bug (serving/profiling.py)
        from repro.serving.profiling import RecompilationTracker
        self.recompiles = RecompilationTracker()
        self._inflight: Dict[int, PrefillCursor] = {}   # slot -> cursor
        self._begin_seq = 0                  # FIFO stamp for cursors
        self._step = jax.jit(make_serve_step(cfg))

        if paged:
            def prefill_paged(params, tokens, starts, q_lens, cache, tables):
                """One co-prefill round: (Bp, C) chunk straight into the
                rows' pool blocks.  ONE compiled program for every wave
                and every prompt length (shapes are all fixed)."""
                batch = {"tokens": tokens, "positions": starts,
                         "q_lens": q_lens, "cache": cache,
                         "block_tables": tables}
                return T.prefill_step(params, cfg, batch)

            self._prefill_paged = jax.jit(prefill_paged, donate_argnums=4)

        def prefill(params, tokens, cache, encoder_output):
            """Replay (B, P) prompt tokens through decode_step via scan."""
            B, P = tokens.shape

            def body(carry, t):
                cache, pos = carry
                batch = {"tokens": tokens[:, t][:, None], "positions": pos,
                         "cache": cache}
                if encoder_output is not None:
                    batch["encoder_output"] = encoder_output
                logits, cache = T.decode_step(params, cfg, batch)
                return (cache, pos + 1), logits[:, 0]

            (cache, pos), logits = jax.lax.scan(
                body, (cache, jnp.zeros((B,), jnp.int32)), jnp.arange(P))
            return cache, pos, logits[-1]

        self._prefill = jax.jit(prefill)     # whole-prompt reference path

        def prefill_chunk_fn(params, tokens, cache, pos0, encoder_output):
            """One fixed-width chunk from dynamic start position ``pos0``:
            tokens (1, C) -> (cache, per-step logits (C, V)).  Compiled
            once; every prompt length reuses the same program."""
            C = tokens.shape[1]

            def body(carry, t):
                cache, pos = carry
                batch = {"tokens": tokens[:, t][:, None], "positions": pos,
                         "cache": cache}
                if encoder_output is not None:
                    batch["encoder_output"] = encoder_output
                logits, cache = T.decode_step(params, cfg, batch)
                return (cache, pos + 1), logits[:, 0]

            (cache, _), logits = jax.lax.scan(
                body, (cache, pos0), jnp.arange(C))
            return cache, logits[:, 0]       # (C, V): batch row 0

        self._prefill_chunk = jax.jit(prefill_chunk_fn, donate_argnums=2)

        tie_eps = self.greedy_tie_eps        # jit closure constant

        def sample(key, logits, temps, greedy):
            # temperatures below epsilon ARE greedy: dividing by a tiny
            # clamp overflows f32 and feeds categorical NaN-producing
            # logits, so route those rows through argmax instead
            greedy = jnp.logical_or(greedy, temps < 1e-4)
            safe_t = jnp.where(greedy, jnp.float32(1.0), temps)
            cat = jax.random.categorical(key, logits / safe_t[:, None])
            if tie_eps > 0.0:
                # deterministic tie break: lowest token id within eps of
                # the max, immune to summation-order noise across the
                # paged/dense layouts (ROADMAP near-tie caveat)
                amax = jnp.max(logits, axis=-1, keepdims=True)
                g_tok = jnp.argmax(logits >= amax - tie_eps, axis=-1)
            else:
                g_tok = jnp.argmax(logits, axis=-1)
            return jnp.where(greedy, g_tok, cat)

        self._sample_vec = jax.jit(sample)

        self._enc_pool = None
        if cfg.family == "encdec":
            self._encode = jax.jit(
                lambda params, frames: T._encode(params["encoder"], cfg,
                                                 frames))
            self._enc_pool = jnp.zeros(
                (max_slots, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))

    @staticmethod
    def _family_supports_prefix(cfg) -> bool:
        if cfg.family == "encdec":       # KV depends on the audio frames too
            return False
        return all(ax is not None
                   for ax in PagedKVCache._seq_axis_per_leaf(cfg, 1))

    # -- scheduler-facing primitives ----------------------------------------

    def prefill_into_slot(self, prompt: np.ndarray,
                          encoder_input: Optional[np.ndarray] = None,
                          *, start_pos: int = 0,
                          prefix_blocks: Sequence[int] = (),
                          ) -> Tuple[int, np.ndarray]:
        """Prefill one prompt into a free slot of the pooled cache.

        ``start_pos > 0`` resumes from a cached prefix: ``prefix_blocks``
        (from :meth:`PrefixCache.lookup`) back positions
        ``[0, start_pos)`` and only ``prompt[start_pos:]`` runs through
        the model, in ``prefill_chunk``-sized pieces.

        Returns ``(slot, last_logits (V,))`` — the scheduler samples the
        first new token from these logits, so admission costs one
        (suffix) prefill and the request joins the very next decode round.
        """
        [(slot, last)] = self.prefill_into_slots(
            [prompt], [encoder_input], start_pos=[start_pos],
            prefix_blocks=[list(prefix_blocks)])
        return slot, last

    def prefill_into_slots(self, prompts: Sequence[np.ndarray],
                           encoder_inputs: Optional[Sequence] = None,
                           *, start_pos: Optional[Sequence[int]] = None,
                           prefix_blocks: Optional[Sequence] = None,
                           ) -> List[Tuple[int, np.ndarray]]:
        """Co-prefill a batch of prompts into free slots, to completion.

        One ``begin_prefill`` + one unbudgeted ``advance_prefill``: the
        wave-at-once shape.  Paged mode packs every round as ONE
        compiled ``(Bp, C)`` chunk program whose K/V lands straight in
        the slots' pool blocks; dense mode (and enc-dec) replays
        batch-1 chunks — identical math, so greedy outputs are
        bit-identical across the two layouts.  All-or-nothing: an error
        anywhere (allocation, prefix load, a prefill round) releases
        every slot the call claimed before it propagates.

        Returns ``[(slot, last_logits (V,))]`` in **input order**.
        """
        cursors = self.begin_prefill(prompts, encoder_inputs,
                                     start_pos=start_pos,
                                     prefix_blocks=prefix_blocks)
        self.advance_prefill(cursors)        # cleans up all slots on error
        # one host-transfer pass AFTER every round dispatched
        return [(c.slot, np.asarray(c.last_logits)) for c in cursors]

    def begin_prefill(self, prompts: Sequence[np.ndarray],
                      encoder_inputs: Optional[Sequence] = None,
                      *, start_pos: Optional[Sequence[int]] = None,
                      prefix_blocks: Optional[Sequence] = None,
                      ) -> List[PrefillCursor]:
        """Claim slots for a batch of prompts and register one in-flight
        :class:`PrefillCursor` per row — no model compute yet beyond the
        enc-dec encoder and prefix-block loads.  Slot allocation is
        all-or-nothing: on ``OutOfBlocks`` every slot claimed so far is
        released before the error propagates.  Cursors persist on the
        engine until ``advance_prefill`` completes them or
        ``cancel_prefill`` abandons them."""
        n = len(prompts)
        prompts = [np.asarray(p, np.int32) for p in prompts]
        encoder_inputs = encoder_inputs or [None] * n
        start_pos = list(start_pos) if start_pos is not None else [0] * n
        prefix_blocks = (list(prefix_blocks) if prefix_blocks is not None
                         else [()] * n)
        for p, sp in zip(prompts, start_pos):
            assert 0 <= sp < len(p), (sp, len(p))
        cursors: List[PrefillCursor] = []
        try:
            for p, e, sp, pb in zip(prompts, encoder_inputs, start_pos,
                                    prefix_blocks):
                slot = self.kv.alloc_slot(len(p))
                cur = PrefillCursor(slot=slot, tokens=p, start_pos=sp,
                                    pos=sp, seq=self._begin_seq,
                                    encoder_input=e,
                                    prefix_blocks=tuple(pb))
                self._begin_seq += 1
                cursors.append(cur)
                if self.paged:
                    if sp:
                        self.kv.load_prefix_blocks_paged(slot, pb)
                elif self.cfg.family == "encdec":
                    cur.enc1 = self._encode(self.params,
                                            jnp.asarray(e)[None])
                    self._enc_pool = self._enc_pool.at[slot].set(cur.enc1[0])
                # the dense batch-1 staging cache materializes lazily at
                # the cursor's first advance chunk: N co-admitted dense
                # prompts waiting their FIFO turn hold N cursors but at
                # most ONE transient stripe, like the old serial path
        except Exception:
            for cur in cursors:              # all-or-nothing
                self.kv.free_slot(cur.slot)
            raise
        for cur in cursors:
            self._inflight[cur.slot] = cur
        self.cached_prefix_tokens += sum(start_pos)
        return cursors

    def _materialize_dense(self, cur: PrefillCursor) -> None:
        """Build the cursor's batch-1 staging cache (dense mode only):
        a fresh ``init_cache`` stripe with the cached prefix loaded."""
        cache1 = T.init_cache(self.cfg, 1, self.max_seq_len)
        self.transient_prefill_bytes = max(
            self.transient_prefill_bytes,
            sum(leaf.nbytes for leaf in jax.tree.leaves(cache1)))
        if cur.start_pos:
            cache1 = self.kv.load_prefix_blocks(cache1, cur.prefix_blocks)
        cur.dense_cache = cache1

    def advance_prefill(self, cursors: Optional[Sequence[PrefillCursor]]
                        = None, token_budget: Optional[int] = None,
                        ) -> List[PrefillCursor]:
        """Run chunk rounds against in-flight prefills (``cursors``
        defaults to every cursor on the engine) until all complete or
        ``token_budget`` *executed* token positions have run — the
        FLOPs/latency proxy: a paged round costs ``prefill_batch *
        prefill_chunk`` whatever the real row contents, a dense chunk
        costs ``prefill_chunk``.  The first round always runs, so a
        budget below one round still makes progress (the budget is a
        cap checked *between* rounds).  Rounds are scheduled FIFO by
        begin order, so a long prompt keeps advancing even under a
        sustained stream of later short admissions — no starvation,
        bounded TTFT for every row.

        Returns the cursors that **completed during this call**, each
        with device-resident ``last_logits``; unfinished cursors stay
        parked on the engine for the next call.  On any error every
        cursor this call touched — finished earlier in the call or
        still in flight — has its slot released before the error
        propagates, so one failed round can never leak slots or blocks.
        """
        working = [c for c in (cursors if cursors is not None
                               else list(self._inflight.values()))
                   if not c.done]
        for c in working:
            assert self._inflight.get(c.slot) is c, \
                f"cursor for slot {c.slot} is not in flight"
        involved = list(working)
        finished: List[PrefillCursor] = []
        spent = 0
        C = self.prefill_chunk
        tr = self.tracer

        def budget_left():
            return (token_budget is None or spent < token_budget
                    or spent == 0)

        try:
            # fault hook INSIDE the all-or-nothing block: an injected
            # prefill fault takes the same slot-release path a real
            # engine error does, so the scheduler's requeue stays exact
            if self.fault_injector is not None:
                self.fault_injector.on_engine_op("prefill")
            working.sort(key=lambda c: c.seq)    # FIFO by begin order
            if self.paged:
                Bp = self.prefill_batch
                while working and budget_left():
                    sel = working[:Bp]
                    tables = np.full((Bp, self.kv.blocks_per_slot),
                                     self.kv.trash_block, np.int32)
                    toks = np.zeros((Bp, C), np.int32)
                    starts = np.zeros(Bp, np.int32)
                    qlens = np.zeros(Bp, np.int32)
                    for r, cur in enumerate(sel):
                        tables[r] = self.kv.table_row(cur.slot)
                        ql = min(cur.remaining, C)
                        toks[r, :ql] = cur.tokens[cur.pos:cur.pos + ql]
                        starts[r] = cur.pos
                        qlens[r] = ql
                    self.recompiles.observe(
                        "prefill_paged", (toks.shape, tables.shape),
                        tracer=tr)
                    logits, self.kv.cache = self._prefill_paged(
                        self.params, jnp.asarray(toks), jnp.asarray(starts),
                        jnp.asarray(qlens), self.kv.cache,
                        jnp.asarray(tables))
                    real = int(qlens.sum())
                    # FLOPs proxy: every row of the compiled (Bp, C)
                    # program executes every round, dummy rows included
                    spent += Bp * C
                    self.prefill_tokens += real
                    self.prefill_tokens_executed += Bp * C
                    self.prefill_tokens_padding += Bp * C - real
                    for r, cur in enumerate(sel):
                        cur.pos += int(qlens[r])
                        if tr is not None and tr.enabled and qlens[r]:
                            tr.prefill_advance(cur.slot, int(qlens[r]),
                                               cur.pos, len(cur.tokens))
                        if cur.done:
                            # device-resident slice: no host sync inside
                            # the round loop, so rounds keep dispatching
                            cur.last_logits = logits[r, int(qlens[r]) - 1]
                            finished.append(cur)
                    working = [c for c in working if not c.done]
            else:
                for cur in working:
                    while not cur.done and budget_left():
                        if cur.dense_cache is None:
                            self._materialize_dense(cur)
                        ql = min(cur.remaining, C)
                        chunk = np.zeros(C, np.int32)
                        chunk[:ql] = cur.tokens[cur.pos:cur.pos + ql]
                        self.recompiles.observe(
                            "prefill_chunk", (1, C), tracer=tr)
                        cur.dense_cache, logits = self._prefill_chunk(
                            self.params, jnp.asarray(chunk)[None],
                            cur.dense_cache,
                            jnp.full((1,), cur.pos, jnp.int32), cur.enc1)
                        li = (len(cur.tokens) - 1) - cur.pos
                        if 0 <= li < C:      # row's last real token here
                            cur.last_logits = logits[li]
                        cur.pos += ql
                        spent += C
                        self.prefill_tokens += ql
                        self.prefill_tokens_executed += C
                        self.prefill_tokens_padding += C - ql
                        if tr is not None and tr.enabled:
                            tr.prefill_advance(cur.slot, ql, cur.pos,
                                               len(cur.tokens))
                    if cur.done:
                        self.kv.write_prefill(cur.slot, cur.dense_cache)
                        cur.dense_cache = None
                        finished.append(cur)
                    if not budget_left():
                        break
        except Exception:
            # all-or-nothing per call: an error anywhere releases every
            # slot this call touched (the caller never learned of the
            # rows that finished just before the failure), so nothing
            # leaks past the caller's error handling
            for cur in involved:
                self._inflight.pop(cur.slot, None)
                self.kv.free_slot(cur.slot)
            raise
        for cur in finished:
            del self._inflight[cur.slot]
        return finished

    def cancel_prefill(self, slot: int) -> None:
        """Abandon an in-flight prefill (mid-prefill preemption): the
        cursor is dropped, the slot and its KV blocks return to the
        pool, and any dense staging cache is discarded.  The caller
        re-queues the request; it resumes later from whatever the prefix
        cache still holds."""
        self._inflight.pop(slot)
        self.kv.free_slot(slot)

    @property
    def inflight_prefill_tokens(self) -> int:
        """Real token positions still to execute across in-flight
        cursors (telemetry; the scheduler's budget debt)."""
        return sum(c.remaining for c in self._inflight.values())

    def decode_once(self, tokens: np.ndarray,
                    positions: np.ndarray) -> jnp.ndarray:
        """One decode step over all slots.  ``tokens``/``positions`` are
        (max_slots,); rows for free slots carry dummies (their cache
        writes land in region the next prefill overwrites).  Returns
        logits (max_slots, V) **on device** — pass them straight to
        ``sample_tokens`` so the step costs one host sync, not two."""
        if self.fault_injector is not None:
            # before any state mutation: a decode-site fault leaves the
            # cache untouched, so the scheduler can retry the same step
            self.fault_injector.on_engine_op("decode")
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[:, None],
                 "positions": jnp.asarray(positions, jnp.int32),
                 "cache": self.kv.cache}
        if self.paged:
            # free slots' rows point at the trash block; their dummy
            # writes and speculative gathers never touch live KV.  A
            # mid-prefill slot's table maps real blocks already, so its
            # row is masked to the trash block too — otherwise its
            # dummy decode write at position 0 would corrupt KV the
            # prefill just produced
            batch["block_tables"] = self.kv.device_block_tables(
                mask_slots=self._inflight)
        if self._enc_pool is not None:
            batch["encoder_output"] = self._enc_pool
        self.recompiles.observe(
            "decode_step", (np.shape(tokens), np.shape(positions)),
            tracer=self.tracer)
        logits, self.kv.cache = self._step(self.params, batch)
        self.decode_steps += 1
        return logits[:, 0]                  # device-resident; no sync here

    def sample_tokens(self, logits: np.ndarray, temps: np.ndarray,
                      greedy: np.ndarray) -> np.ndarray:
        """Per-row sampling: row i uses temps[i] / greedy[i].  Rows whose
        temperature is below 1e-4 (including exactly 0.0) sample greedily."""
        self.key, sub = jax.random.split(self.key)
        self.recompiles.observe("sample", np.shape(logits),
                                tracer=self.tracer)
        # deliberate: THE one host sync per step — the scheduler needs
        # concrete token ids for EOS/retirement bookkeeping
        return np.asarray(self._sample_vec(  # repro-lint: disable=RL001
            sub, jnp.asarray(logits), jnp.asarray(temps, jnp.float32),
            jnp.asarray(greedy)))

    def free_slot(self, slot: int) -> None:
        self.kv.free_slot(slot)

    # -- compatibility wrapper ----------------------------------------------

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Serve a batch of requests through the scheduler path and return
        generated tokens in submission order."""
        from repro.serving.scheduler import Scheduler
        sched = Scheduler(self)
        rids = [sched.submit(r) for r in requests]
        sched.run()
        return [sched.output(rid) for rid in rids]
