"""Batched serving engine: slot-granular prefill/decode primitives.

The serving counterpart of the deployment story: the same capsule image
serves a model with continuously batched requests.  The engine owns the
pooled decode cache (a :class:`~repro.serving.kvcache.PagedKVCache` over
``max_slots`` sequences) and exposes the primitives the scheduler drives:

* ``prefill_into_slot`` — replay one prompt through ``decode_step`` in
  fixed-size *chunks* under a ``lax.scan`` at batch 1, scatter the
  resulting cache into a freed slot, and return the last-token logits
  (the first sample comes from these, so TTFT is one prefill, not one
  full decode round).  Chunking bounds recompiles to ONE prefill program
  regardless of prompt length, and the ``start_pos`` resume path lets a
  prompt whose prefix is already resident in the prefix store skip
  straight to its first uncached token: the cached KV blocks are loaded
  into the batch-1 cache and only the suffix chunks execute.
* ``decode_once`` — one token for every slot against the pooled cache;
  ``serve_step`` here is the exact program the decode dry-run shapes
  lower.

Sampling is vectorized per slot (``sample_tokens``): each row gets its own
temperature / greedy flag, fixing the seed bug where ``requests[0].params``
was applied to the whole batch.  ``generate()`` survives as a thin
compatibility wrapper that routes through the continuous-batching
scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.serving.kvcache import PagedKVCache


@dataclass
class SamplingParams:
    temperature: float = 1.0
    greedy: bool = False
    max_new_tokens: int = 32
    eos_token: Optional[int] = None      # early-exit on this token id


@dataclass
class Request:
    prompt: np.ndarray                       # (prompt_len,) int32
    params: SamplingParams = field(default_factory=SamplingParams)
    # enc-dec (whisper): precomputed frame embeddings (enc_seq, d_model);
    # the engine runs the encoder once at prefill
    encoder_input: Optional[np.ndarray] = None


def make_serve_step(cfg, *, long_context: bool = False):
    """serve_step(params, batch) -> (logits, new_cache); batch carries
    tokens (B,1), positions (B,), cache (and encoder_output / mrope)."""
    def serve_step(params, batch):
        return T.decode_step(params, cfg, batch, long_context=long_context)
    return serve_step


class ServingEngine:
    """Fixed-slot batched engine (continuous batching over ``max_slots``).

    ``prefix_cache_blocks > 0`` turns on the prefix-cache subsystem (see
    :mod:`repro.serving.prefix_cache`): the paged cache grows a prefix
    store of that many KV blocks and ``self.prefix_cache`` holds the
    radix index the scheduler probes at admission.  Families whose decode
    cache is not positional (SSM/hybrid state) or whose KV depends on
    more than the token ids (enc-dec) silently leave it disabled.

    ``paged=True`` switches the decode cache to physical block storage
    gathered through per-slot block tables by the Pallas paged-attention
    kernel; ``num_blocks`` then sizes the KV pool (default: worst case),
    and sizing it *below* ``max_slots * ceil(max_seq_len/block_size)``
    makes ``OutOfBlocks`` a real event the scheduler handles by deferring
    admissions and preempting decode — the memory-oversubscription mode
    that lets one replica serve more concurrent sequences than the dense
    layout at the same KV budget.  Requires a positional, non-int8
    attention cache (dense / MoE / VLM families).
    """

    def __init__(self, cfg, params, max_seq_len: int, max_slots: int = 8,
                 rng_seed: int = 0, kv_block_size: int = 16,
                 prefix_cache_blocks: int = 0, prefill_chunk: int = 16,
                 paged: bool = False, num_blocks: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_seq_len = max_seq_len
        self.max_slots = max_slots
        self.key = jax.random.PRNGKey(rng_seed)
        self.prefill_chunk = prefill_chunk
        self.paged = paged
        want_prefix = prefix_cache_blocks > 0
        self.kv = PagedKVCache(
            cfg, max_slots, max_seq_len, block_size=kv_block_size,
            prefix_blocks=(prefix_cache_blocks if want_prefix and
                           self._family_supports_prefix(cfg) else 0),
            num_blocks=num_blocks, paged=paged)
        self.prefix_cache = None
        if self.kv.prefix_pool is not None:
            from repro.serving.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(self.kv)
        self.decode_steps = 0                # accounting (tested)
        self.prefill_tokens = 0              # real tokens run through prefill
        self.prefill_tokens_executed = 0     # incl. chunk padding (FLOPs proxy)
        self.cached_prefix_tokens = 0        # tokens served from the store
        self._step = jax.jit(make_serve_step(cfg))

        def prefill(params, tokens, cache, encoder_output):
            """Replay (B, P) prompt tokens through decode_step via scan."""
            B, P = tokens.shape

            def body(carry, t):
                cache, pos = carry
                batch = {"tokens": tokens[:, t][:, None], "positions": pos,
                         "cache": cache}
                if encoder_output is not None:
                    batch["encoder_output"] = encoder_output
                logits, cache = T.decode_step(params, cfg, batch)
                return (cache, pos + 1), logits[:, 0]

            (cache, pos), logits = jax.lax.scan(
                body, (cache, jnp.zeros((B,), jnp.int32)), jnp.arange(P))
            return cache, pos, logits[-1]

        self._prefill = jax.jit(prefill)     # whole-prompt reference path

        def prefill_chunk_fn(params, tokens, cache, pos0, encoder_output):
            """One fixed-width chunk from dynamic start position ``pos0``:
            tokens (1, C) -> (cache, per-step logits (C, V)).  Compiled
            once; every prompt length reuses the same program."""
            C = tokens.shape[1]

            def body(carry, t):
                cache, pos = carry
                batch = {"tokens": tokens[:, t][:, None], "positions": pos,
                         "cache": cache}
                if encoder_output is not None:
                    batch["encoder_output"] = encoder_output
                logits, cache = T.decode_step(params, cfg, batch)
                return (cache, pos + 1), logits[:, 0]

            (cache, _), logits = jax.lax.scan(
                body, (cache, pos0), jnp.arange(C))
            return cache, logits[:, 0]       # (C, V): batch row 0

        self._prefill_chunk = jax.jit(prefill_chunk_fn, donate_argnums=2)

        def sample(key, logits, temps, greedy):
            # temperatures below epsilon ARE greedy: dividing by a tiny
            # clamp overflows f32 and feeds categorical NaN-producing
            # logits, so route those rows through argmax instead
            greedy = jnp.logical_or(greedy, temps < 1e-4)
            safe_t = jnp.where(greedy, jnp.float32(1.0), temps)
            cat = jax.random.categorical(key, logits / safe_t[:, None])
            return jnp.where(greedy, jnp.argmax(logits, axis=-1), cat)

        self._sample_vec = jax.jit(sample)

        self._enc_pool = None
        if cfg.family == "encdec":
            self._encode = jax.jit(
                lambda params, frames: T._encode(params["encoder"], cfg,
                                                 frames))
            self._enc_pool = jnp.zeros(
                (max_slots, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))

    @staticmethod
    def _family_supports_prefix(cfg) -> bool:
        if cfg.family == "encdec":       # KV depends on the audio frames too
            return False
        return all(ax is not None
                   for ax in PagedKVCache._seq_axis_per_leaf(cfg, 1))

    # -- scheduler-facing primitives ----------------------------------------

    def prefill_into_slot(self, prompt: np.ndarray,
                          encoder_input: Optional[np.ndarray] = None,
                          *, start_pos: int = 0,
                          prefix_blocks: Sequence[int] = (),
                          ) -> Tuple[int, np.ndarray]:
        """Prefill one prompt into a free slot of the pooled cache.

        ``start_pos > 0`` resumes from a cached prefix: ``prefix_blocks``
        (from :meth:`PrefixCache.lookup`) are loaded into positions
        ``[0, start_pos)`` and only ``prompt[start_pos:]`` runs through
        the model, in ``prefill_chunk``-sized pieces.

        Returns ``(slot, last_logits (V,))`` — the scheduler samples the
        first new token from these logits, so admission costs one
        (suffix) prefill and the request joins the very next decode round.
        """
        prompt = np.asarray(prompt, np.int32)
        P = len(prompt)
        assert 0 <= start_pos < P, (start_pos, P)
        slot = self.kv.alloc_slot(P)
        enc1 = None
        if self.cfg.family == "encdec":
            enc1 = self._encode(self.params,
                                jnp.asarray(encoder_input)[None])
            self._enc_pool = self._enc_pool.at[slot].set(enc1[0])
        cache1 = T.init_cache(self.cfg, 1, self.max_seq_len)
        if start_pos:
            cache1 = self.kv.load_prefix_blocks(cache1, prefix_blocks)
        C = self.prefill_chunk
        n = P - start_pos
        n_chunks = -(-n // C)
        padded = np.zeros(n_chunks * C, np.int32)
        padded[:n] = prompt[start_pos:]
        last_logits = None
        pos = start_pos
        for c in range(n_chunks):
            chunk = jnp.asarray(padded[c * C:(c + 1) * C])[None]
            cache1, logits = self._prefill_chunk(
                self.params, chunk, cache1,
                jnp.full((1,), pos, jnp.int32), enc1)
            li = (P - 1) - pos               # last real token in this chunk?
            if 0 <= li < C:
                last_logits = logits[li]
            pos += C
        self.kv.write_prefill(slot, cache1)
        self.prefill_tokens += n
        self.prefill_tokens_executed += n_chunks * C
        self.cached_prefix_tokens += start_pos
        return slot, np.asarray(last_logits)

    def decode_once(self, tokens: np.ndarray,
                    positions: np.ndarray) -> np.ndarray:
        """One decode step over all slots.  ``tokens``/``positions`` are
        (max_slots,); rows for free slots carry dummies (their cache
        writes land in region the next prefill overwrites).  Returns
        logits (max_slots, V)."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)[:, None],
                 "positions": jnp.asarray(positions, jnp.int32),
                 "cache": self.kv.cache}
        if self.paged:
            # free slots' rows point at the trash block; their dummy
            # writes and speculative gathers never touch live KV
            batch["block_tables"] = self.kv.device_block_tables()
        if self._enc_pool is not None:
            batch["encoder_output"] = self._enc_pool
        logits, self.kv.cache = self._step(self.params, batch)
        self.decode_steps += 1
        return np.asarray(logits[:, 0])

    def sample_tokens(self, logits: np.ndarray, temps: np.ndarray,
                      greedy: np.ndarray) -> np.ndarray:
        """Per-row sampling: row i uses temps[i] / greedy[i].  Rows whose
        temperature is below 1e-4 (including exactly 0.0) sample greedily."""
        self.key, sub = jax.random.split(self.key)
        return np.asarray(self._sample_vec(
            sub, jnp.asarray(logits), jnp.asarray(temps, jnp.float32),
            jnp.asarray(greedy)))

    def free_slot(self, slot: int) -> None:
        self.kv.free_slot(slot)

    # -- compatibility wrapper ----------------------------------------------

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Serve a batch of requests through the scheduler path and return
        generated tokens in submission order."""
        from repro.serving.scheduler import Scheduler
        sched = Scheduler(self)
        rids = [sched.submit(r) for r in requests]
        sched.run()
        return [sched.output(rid) for rid in rids]
