"""Batched serving engine: KV-cache management, prefill, decode, sampling.

The serving counterpart of the deployment story: the same capsule image
serves a model with batched requests.  The engine keeps one ragged batch of
sequences; prefill replays prompt tokens through ``decode_step`` under a
``lax.scan`` (compiled once), decode samples one token per step for every
live sequence.  ``serve_step`` — one token against a seq_len cache — is the
exact program the decode dry-run shapes lower.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclass
class SamplingParams:
    temperature: float = 1.0
    greedy: bool = False
    max_new_tokens: int = 32


@dataclass
class Request:
    prompt: np.ndarray                       # (prompt_len,) int32
    params: SamplingParams = field(default_factory=SamplingParams)
    # enc-dec (whisper): precomputed frame embeddings (enc_seq, d_model);
    # the engine runs the encoder once at prefill
    encoder_input: Optional[np.ndarray] = None


def make_serve_step(cfg, *, long_context: bool = False):
    """serve_step(params, batch) -> (logits, new_cache); batch carries
    tokens (B,1), positions (B,), cache (and encoder_output / mrope)."""
    def serve_step(params, batch):
        return T.decode_step(params, cfg, batch, long_context=long_context)
    return serve_step


class ServingEngine:
    """Fixed-slot batched engine (continuous batching over ``max_slots``)."""

    def __init__(self, cfg, params, max_seq_len: int, max_slots: int = 8,
                 rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq_len = max_seq_len
        self.max_slots = max_slots
        self.key = jax.random.PRNGKey(rng_seed)
        self._step = jax.jit(make_serve_step(cfg))

        def prefill(params, tokens, cache, encoder_output):
            """Replay (B, P) prompt tokens through decode_step via scan."""
            B, P = tokens.shape

            def body(carry, t):
                cache, pos = carry
                batch = {"tokens": tokens[:, t][:, None], "positions": pos,
                         "cache": cache}
                if encoder_output is not None:
                    batch["encoder_output"] = encoder_output
                logits, cache = T.decode_step(params, cfg, batch)
                return (cache, pos + 1), logits[:, 0]

            (cache, pos), logits = jax.lax.scan(
                body, (cache, jnp.zeros((B,), jnp.int32)), jnp.arange(P))
            return cache, pos, logits[-1]

        self._prefill = jax.jit(prefill)
        if cfg.family == "encdec":
            self._encode = jax.jit(
                lambda params, frames: T._encode(params["encoder"], cfg,
                                                 frames))

    def _sample(self, logits, sp: SamplingParams):
        if sp.greedy:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / max(sp.temperature, 1e-4))

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Serve a batch of requests (padded to equal prompt length)."""
        assert len(requests) <= self.max_slots
        B = len(requests)
        P = max(len(r.prompt) for r in requests)
        prompts = np.zeros((B, P), np.int32)
        for i, r in enumerate(requests):
            prompts[i, P - len(r.prompt):] = r.prompt      # left-pad
        enc_out = None
        if self.cfg.family == "encdec":
            frames = jnp.stack([jnp.asarray(r.encoder_input)
                                for r in requests])
            enc_out = self._encode(self.params, frames)
        cache = T.init_cache(self.cfg, B, self.max_seq_len)
        cache, pos, last_logits = self._prefill(self.params,
                                                jnp.asarray(prompts), cache,
                                                enc_out)
        max_new = max(r.params.max_new_tokens for r in requests)
        outs = []
        tok = self._sample(last_logits, requests[0].params)
        for _ in range(max_new):
            outs.append(tok)
            batch = {"tokens": tok[:, None], "positions": pos,
                     "cache": cache}
            if enc_out is not None:
                batch["encoder_output"] = enc_out
            logits, cache = self._step(self.params, batch)
            pos = pos + 1
            tok = self._sample(logits[:, 0], requests[0].params)
        gen = np.stack([np.asarray(o) for o in outs], axis=1)    # (B, new)
        return [gen[i, :requests[i].params.max_new_tokens] for i in range(B)]
