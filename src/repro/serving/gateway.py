"""Multi-replica serving gateway: least-loaded dispatch + graceful drain.

Scale-out layer of the serving story.  Each replica is one
:class:`~repro.serving.scheduler.Scheduler` over one engine — conceptually
one ``ch-run`` capsule instance of the same immutable image, the way the
paper's deployment runs one containerized process per allocation.  The
gateway front-ends N replicas:

* ``submit`` routes each request to the replica with the smallest load
  (queue depth + live slots);
* ``step`` advances every replica one decode round (single-host stand-in
  for replicas running concurrently on their own nodes);
* ``drain`` closes admission and runs every replica until all in-flight
  requests complete — the graceful-shutdown path a rolling image update
  needs (the capsule is immutable, so an update is drain + relaunch).

``launch_capsule_replicas`` builds the engines *inside* ``ch-run``
launches via :class:`~repro.core.container.CapsuleRuntime`, recording the
per-replica capsule bookkeeping (image, uid map, scrubbed env) on the
handle; unit tests may also construct replicas from bare engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import merge_summaries
from repro.serving.scheduler import Scheduler


@dataclass
class CapsuleReplica:
    """One serving replica + its launch bookkeeping."""
    name: str
    scheduler: Scheduler
    capsule: Optional[Dict[str, Any]] = None   # image/uid_map/env of ch-run
    routed: int = 0

    @property
    def load(self) -> int:
        return self.scheduler.load


class ReplicaGateway:
    """Least-loaded request router over N scheduler replicas."""

    def __init__(self, replicas: List[CapsuleReplica]):
        assert replicas, "gateway needs at least one replica"
        self.replicas = replicas
        self.draining = False

    @classmethod
    def from_engines(cls, engines: List[ServingEngine],
                     **sched_kw) -> "ReplicaGateway":
        return cls([CapsuleReplica(f"replica{i}", Scheduler(e, **sched_kw))
                    for i, e in enumerate(engines)])

    # -- routing -------------------------------------------------------------

    def submit(self, request: Request) -> Tuple[int, int]:
        """Route to the least-loaded replica; returns a (replica, rid)
        handle usable with :meth:`result`."""
        if self.draining:
            raise RuntimeError("gateway is draining; admission closed")
        idx = min(range(len(self.replicas)),
                  key=lambda i: (self.replicas[i].load, i))
        rep = self.replicas[idx]
        rep.routed += 1
        return idx, rep.scheduler.submit(request)

    # -- progress ------------------------------------------------------------

    def step(self) -> bool:
        """One decode round on every replica with work."""
        progressed = False
        for rep in self.replicas:
            if rep.scheduler.has_work:
                progressed = rep.scheduler.step() or progressed
        return progressed

    @property
    def has_work(self) -> bool:
        return any(r.scheduler.has_work for r in self.replicas)

    def run(self) -> None:
        while self.has_work:
            self.step()

    def drain(self) -> None:
        """Graceful drain: no new admissions, all in-flight complete."""
        self.draining = True
        for rep in self.replicas:
            rep.scheduler.draining = True
        self.run()

    # -- results / telemetry -------------------------------------------------

    def result(self, handle: Tuple[int, int]) -> np.ndarray:
        idx, rid = handle
        return self.replicas[idx].scheduler.output(rid)

    def stats(self) -> Dict[str, Any]:
        summaries = [rep.scheduler.metrics.summary() for rep in self.replicas]
        per = {rep.name: {**s, "routed": rep.routed, "capsule": rep.capsule}
               for rep, s in zip(self.replicas, summaries)}
        return {"replicas": per, "totals": merge_summaries(summaries)}


def launch_capsule_replicas(
        n: int, engine_factory: Callable[[], ServingEngine], work_dir,
        image_definition=None) -> Tuple[ReplicaGateway, Any]:
    """Deploy one immutable image and launch ``n`` serving replicas from
    it, each engine constructed inside a ``CapsuleRuntime.run`` (the
    ``ch-run`` analogue) so the launch bookkeeping — image hash, uid map,
    scrubbed env — is recorded per replica.  Returns (gateway, deployment).
    """
    from repro.core import deploy as D

    pipe = D.DeploymentPipeline()
    definition = image_definition or D.intel_tensorflow_image(
        "serving-replica")
    dep = pipe.deploy(definition, Path(work_dir))
    replicas = []
    for r in range(n):
        res = dep.run(engine_factory, ranks=1)[0]
        replicas.append(CapsuleReplica(
            f"replica{r}", Scheduler(res.value),
            capsule={"image": res.image, "uid_map": res.uid_map,
                     "env": res.env, "wall_time_s": res.wall_time_s}))
    return ReplicaGateway(replicas), dep
