"""Multi-replica serving gateway: routing, health, failover, drain.

Scale-out layer of the serving story.  Each replica is one
:class:`~repro.serving.scheduler.Scheduler` over one engine — conceptually
one ``ch-run`` capsule instance of the same immutable image, the way the
paper's deployment runs one containerized process per allocation.  The
gateway front-ends N replicas:

* ``submit`` routes with *prefix affinity*: the request goes to the
  replica whose prefix cache holds the longest prefix of its prompt
  (ties and misses broken by least load).  When no replica has the
  prefix yet, the first block of token ids is hashed to pick a stable
  owner — so every request opening with the same system prompt lands on
  the same capsule and warms a single cache instead of N — unless that
  owner is overloaded by more than ``affinity_slack`` requests relative
  to the least-loaded replica, in which case load wins;
* ``step`` advances every *routable* replica one decode round and feeds
  each replica's :class:`~repro.serving.health.HealthMonitor` with the
  one signal a wedged capsule cannot fake: whether the scheduler's
  observable state actually changed (progress signature);
* ``drain`` closes admission and runs every replica until all in-flight
  requests complete or fail over — the graceful-shutdown path a rolling
  image update needs (the capsule is immutable, so an update is drain +
  relaunch).

Failure handling (PR 9) — nodes fail and batch schedulers preempt
allocations on the paper's systems, so the fleet must survive a replica:

* **Health membership.**  HEALTHY -> DEGRADED -> QUARANTINED (salvage +
  optional auto-rejoin after a cooldown) or -> DEAD (a crashed capsule;
  terminal).  Transitions are edge-triggered ``replica_health`` events.
* **Failover.**  A replica leaving the routable set has its queued and
  in-flight requests salvaged (``Scheduler.abort()``: slots/pins freed,
  emitted-so-far tokens kept) and re-routed to survivors under a
  per-request retry budget with exponential backoff — the resume is the
  recompute-preemption path (re-prefill prompt + emitted[:-1]), so
  greedy outputs stay bit-identical to a fault-free run.  A request that
  exhausts its budget resolves to a typed :class:`RequestFailed` from
  :meth:`result` — never a stranded handle, never a bare exception.
* **Graceful degradation.**  Under a configured
  :class:`DegradationPolicy`, sustained SLO breaches or fleet-wide
  queue exhaustion shed load (:class:`Overloaded` at submit), shrink
  every replica's ``prefill_token_budget``, and cap over-budget
  tenants' ``max_new_tokens`` — all edge-triggered ``overload_*``
  events, all restored when pressure clears.
* **Watchdog.**  ``run()``/``drain()`` raise after ``stall_patience``
  consecutive no-progress gateway steps instead of spinning forever —
  quarantine normally resolves a wedged replica long before that.

``launch_capsule_replicas`` builds the engines *inside* ``ch-run``
launches via :class:`~repro.core.container.CapsuleRuntime`, recording the
per-replica capsule bookkeeping (image, uid map, scrubbed env) on the
handle; unit tests may also construct replicas from bare engines.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import FaultPlan, ReplicaCrashed
from repro.serving.health import (DEAD, HEALTHY, QUARANTINED, HealthConfig,
                                  HealthMonitor)
from repro.serving.metrics import merge_summaries
from repro.serving.scheduler import Scheduler
from repro.serving.tracing import (Tracer, export_jsonl,
                                   export_chrome_trace, merge_traces)


class Overloaded(RuntimeError):
    """Submit rejected: the fleet is shedding load (degraded mode) or
    has no routable replica left.  Typed so callers can back off and
    retry instead of treating it as a server bug."""


@dataclass
class RequestFailed:
    """Terminal typed failure returned by :meth:`ReplicaGateway.result`
    for a request that exhausted its retry budget (or had no replica
    left to retry on).  A value, not an exception: drain() resolves
    every handle to either tokens or one of these."""
    handle: Tuple[int, int]
    rid: int                       # rid on the last replica that held it
    reason: str
    attempts: int
    last_error: str = ""


@dataclass(frozen=True)
class RetryPolicy:
    """Per-request failover budget.  Backoff is measured in *gateway
    steps* (the scheduler's unit of time): retry ``i`` waits
    ``backoff_base_steps * backoff_factor**(i-1)`` steps before
    re-routing, so a flapping fleet is not hammered."""
    max_retries: int = 3
    backoff_base_steps: int = 1
    backoff_factor: int = 2

    def backoff_steps(self, attempt: int) -> int:
        return self.backoff_base_steps * self.backoff_factor ** max(
            attempt - 1, 0)


@dataclass(frozen=True)
class DegradationPolicy:
    """When and how the gateway sheds load instead of collapsing.

    Degraded mode *arms* when the fleet queue depth reaches
    ``shed_queue_depth`` (immediately — pool exhaustion is not a trend)
    or when any tenant's SLO breach stays active for ``breach_steps``
    consecutive gateway steps; it *releases* after ``recover_steps``
    consecutive clear steps.  While degraded: submits past the shed
    depth raise :class:`Overloaded`, every replica's
    ``prefill_token_budget`` is shrunk by ``budget_shrink`` (restored
    on release), and requests from tenants in active breach get
    ``max_new_tokens`` capped at ``max_new_cap``."""
    shed_queue_depth: Optional[int] = None
    breach_steps: int = 16
    recover_steps: int = 8
    budget_shrink: float = 0.5
    max_new_cap: Optional[int] = None

    def __post_init__(self):
        if not 0.0 < self.budget_shrink <= 1.0:
            raise ValueError(
                f"budget_shrink must be in (0, 1], got {self.budget_shrink}")
        if self.breach_steps <= 0 or self.recover_steps <= 0:
            raise ValueError("breach/recover step thresholds must be "
                             "positive")


@dataclass
class CapsuleReplica:
    """One serving replica + its launch bookkeeping."""
    name: str
    scheduler: Scheduler
    capsule: Optional[Dict[str, Any]] = None   # image/uid_map/env of ch-run
    routed: int = 0

    @property
    def load(self) -> int:
        return self.scheduler.load


@dataclass
class _GatewayRequest:
    """Gateway-side request record: survives replica failures (the
    scheduler-side state dies with its replica)."""
    handle: Tuple[int, int]            # the (replica, rid) submit returned
    request: Request
    current: Tuple[int, int]           # where it lives NOW
    attempts: int = 0
    emitted: List[int] = field(default_factory=list)   # salvaged tokens
    output: Optional[np.ndarray] = None
    failed: Optional[RequestFailed] = None
    last_error: str = ""


class ReplicaGateway:
    """Prefix-affine, load-balanced, health-checked router over N
    replicas."""

    def __init__(self, replicas: List[CapsuleReplica],
                 affinity_slack: int = 2,
                 health: Optional[HealthConfig] = None,
                 retry: Optional[RetryPolicy] = None,
                 degradation: Optional[DegradationPolicy] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 stall_patience: int = 64):
        if not replicas:
            raise ValueError("gateway needs at least one replica")
        self.replicas = replicas
        self.affinity_slack = affinity_slack
        self.draining = False
        self.health_config = health or HealthConfig()
        self.health = [HealthMonitor(self.health_config) for _ in replicas]
        self.retry = retry or RetryPolicy()
        self.degradation = degradation
        if stall_patience <= 0:
            raise ValueError(
                f"stall_patience must be positive, got {stall_patience}")
        self.stall_patience = stall_patience
        if fault_plan is not None:
            for rep in replicas:
                inj = fault_plan.injector_for(rep.name)
                rep.scheduler.fault_injector = inj
                rep.scheduler.engine.fault_injector = inj
        # request registry: every handle submit() ever returned maps to
        # a record; _live tracks where each unresolved record currently
        # lives (rewritten on every failover re-route)
        self._requests: Dict[Tuple[int, int], _GatewayRequest] = {}
        self._live: Dict[Tuple[int, int], _GatewayRequest] = {}
        self._retry_queue: List[Tuple[int, _GatewayRequest]] = []
        self._gstep = 0                    # gateway step counter
        self._quarantined_at: List[Optional[int]] = [None] * len(replicas)
        self.failovers = 0
        self.shed_requests = 0
        self.capped_requests = 0
        # degradation state
        self.degraded = False
        self.degraded_transitions = 0
        self._breach_run = 0
        self._ok_run = 0
        self._saved_budgets: Dict[int, Optional[int]] = {}

    @classmethod
    def from_engines(cls, engines: List[ServingEngine], *,
                     affinity_slack: int = 2, tracing: bool = False,
                     trace_buffer_events: Optional[int] = None,
                     slo_config=None, health=None, retry=None,
                     degradation=None, fault_plan=None,
                     stall_patience: int = 64,
                     **sched_kw) -> "ReplicaGateway":
        """``tracing=True`` gives every replica an enabled
        :class:`~repro.serving.tracing.Tracer` (ring depth
        ``trace_buffer_events``) on the shared process clock, so
        :meth:`trace_events` can interleave the fleet's buffers into one
        timeline.  ``slo_config`` (an
        :class:`~repro.serving.slo.SLOConfig`) arms every replica's
        tracer with its own :class:`~repro.serving.slo.SLOMonitor` —
        breach state is per replica, the policies are shared.
        ``health`` / ``retry`` / ``degradation`` / ``fault_plan``
        configure the failure-handling layer (see the module docs)."""
        def sched(i, e):
            kw = dict(sched_kw)
            if "tracer" not in kw:
                tkw = {"enabled": tracing, "name": f"replica{i}"}
                if trace_buffer_events is not None:
                    tkw["buffer_events"] = trace_buffer_events
                if slo_config is not None:
                    from repro.serving.slo import SLOMonitor
                    tkw["slo"] = SLOMonitor(slo_config)
                kw["tracer"] = Tracer(**tkw)
            return Scheduler(e, **kw)

        return cls([CapsuleReplica(f"replica{i}", sched(i, e))
                    for i, e in enumerate(engines)],
                   affinity_slack=affinity_slack, health=health,
                   retry=retry, degradation=degradation,
                   fault_plan=fault_plan, stall_patience=stall_patience)

    # -- routing -------------------------------------------------------------

    def _routable(self) -> List[int]:
        return [i for i in range(len(self.replicas))
                if self.health[i].routable]

    def _least_loaded(self, candidates: List[int]) -> int:
        return min(candidates,
                   key=lambda i: (self.replicas[i].load, i))

    def _route(self, request: Request) -> Tuple[int, str, int]:
        """Prefix affinity first, hash ownership second, load third —
        over *routable* replicas only.  Returns ``(replica index,
        reason, prefix match length)`` so the decision is traceable,
        not just its outcome."""
        alive = self._routable()
        if not alive:
            raise Overloaded(
                "no routable replica: every replica is quarantined or "
                "dead")
        floor = min(self.replicas[i].load for i in alive)
        matches = {i: self.replicas[i].scheduler.prefix_match_len(
            request.prompt) for i in alive}
        best = max(matches.values())
        if best > 0:
            idx = min((i for i in alive if matches[i] == best),
                      key=lambda i: (self.replicas[i].load, i))
            # a warm cache is not worth unbounded queueing: same slack
            # rule as hash ownership
            if self.replicas[idx].load <= floor + self.affinity_slack:
                return idx, "prefix_affinity", best
        caching = [i for i in alive
                   if self.replicas[i].scheduler.prefix_cache is not None]
        if caching and len(request.prompt) > 0:
            # stable owner for a not-yet-cached prefix: hash the first
            # KV block's worth of token ids
            kv = self.replicas[caching[0]].scheduler.engine.kv
            head = np.asarray(request.prompt[:kv.block_size], np.int32)
            owner = caching[zlib.crc32(head.tobytes()) % len(caching)]
            if self.replicas[owner].load <= floor + self.affinity_slack:
                return owner, "hash_owner", best
        return self._least_loaded(alive), "least_loaded", best

    def _fleet_queue_depth(self) -> int:
        return sum(len(r.scheduler.queue) for r in self.replicas)

    def _breached_tenants(self) -> set:
        out = set()
        for rep in self.replicas:
            mon = rep.scheduler.tracer.slo
            if mon is not None:
                out.update(b["tenant"] for b in mon.active_breaches())
        return out

    def submit(self, request: Request) -> Tuple[int, int]:
        """Route with prefix affinity / least load; returns a
        (replica, rid) handle usable with :meth:`result`.  Raises
        :class:`Overloaded` when no replica is routable or the
        degradation ladder is shedding."""
        if self.draining:
            raise RuntimeError("gateway is draining; admission closed")
        pol = self.degradation
        if self.degraded and pol is not None:
            if (pol.shed_queue_depth is not None
                    and self._fleet_queue_depth() >= pol.shed_queue_depth):
                self.shed_requests += 1
                self.replicas[0].scheduler.tracer.shed(request.tenant)
                raise Overloaded(
                    f"degraded: fleet queue depth "
                    f"{self._fleet_queue_depth()} at/over shed threshold "
                    f"{pol.shed_queue_depth}")
            if (pol.max_new_cap is not None
                    and request.tenant in self._breached_tenants()
                    and request.params.max_new_tokens > pol.max_new_cap):
                # over-budget tenant: serve a shorter answer rather
                # than shed — the cap is traced per request below
                orig = request.params.max_new_tokens
                request = Request(request.prompt,
                                  replace(request.params,
                                          max_new_tokens=pol.max_new_cap),
                                  encoder_input=request.encoder_input,
                                  tenant=request.tenant)
                self.capped_requests += 1
                idx, reason, match_len = self._route(request)
                rid = self._do_submit(idx, request, reason, match_len)
                self.replicas[idx].scheduler.tracer.overload_cap(
                    rid, request.tenant, orig, pol.max_new_cap)
                return idx, rid
        idx, reason, match_len = self._route(request)
        rid = self._do_submit(idx, request, reason, match_len)
        return idx, rid

    def _do_submit(self, idx: int, request: Request, reason: str,
                   match_len: int) -> int:
        rep = self.replicas[idx]
        rep.routed += 1
        rid = rep.scheduler.submit(request)
        rep.scheduler.tracer.route(rid, rep.name, reason, match_len,
                                   rep.load)
        rec = _GatewayRequest(handle=(idx, rid), request=request,
                              current=(idx, rid))
        self._requests[(idx, rid)] = rec
        self._live[(idx, rid)] = rec
        return rid

    # -- progress + health ---------------------------------------------------

    @staticmethod
    def _progress_sig(sched: Scheduler) -> tuple:
        """Everything a genuine unit of scheduler work changes at least
        one of.  An injected (or real) wedge that returns True from
        step() without doing anything leaves this identical — the
        signal the health monitor runs on."""
        eng = sched.engine
        m = sched.metrics
        return (eng.decode_steps, eng.prefill_tokens_executed,
                m.requests_completed, sched.preemptions,
                len(sched.queue), len(sched.active),
                len(sched.prefilling), len(sched.done), sched._next_rid)

    def step(self) -> bool:
        """One decode round on every routable replica with work, plus
        health bookkeeping, quarantine auto-rejoin, pending retries,
        and the degradation-ladder update.  Returns True when anything
        observable happened."""
        self._gstep += 1
        progressed = False
        for i, rep in enumerate(self.replicas):
            mon = self.health[i]
            if mon.state == QUARANTINED:
                qat = self._quarantined_at[i]
                if (self.health_config.auto_rejoin and qat is not None
                        and self._gstep - qat
                        >= self.health_config.rejoin_cooldown_steps):
                    self.rejoin(i)
                    progressed = True
                continue
            if mon.state == DEAD:
                continue
            sched = rep.scheduler
            if not sched.has_work:
                continue
            sig0 = self._progress_sig(sched)
            try:
                sched.step()
            except Exception as e:   # noqa: BLE001 — replica failure
                tr = mon.record_failure(repr(e),
                                        fatal=isinstance(e, ReplicaCrashed))
                self._note_transition(i, tr)
                progressed = True    # the failure was handled — that
                continue             # counts against the watchdog
            made = self._progress_sig(sched) != sig0
            tr = mon.record_step(made)
            self._note_transition(i, tr)
            progressed = made or progressed
        progressed = self._pump_retries() or progressed
        self._update_degradation()
        return progressed

    def _note_transition(self, i: int,
                         tr: Optional[Dict[str, object]]) -> None:
        if tr is None:
            return
        rep = self.replicas[i]
        rep.scheduler.tracer.replica_health(
            rep.name, str(tr["from"]), str(tr["to"]), str(tr["reason"]),
            int(tr["consecutive_bad"]))  # type: ignore[call-overload]
        if tr["to"] == QUARANTINED:
            self._quarantined_at[i] = self._gstep
        if tr["to"] in (QUARANTINED, DEAD):
            self._salvage(i, str(tr["reason"]))

    # -- failover ------------------------------------------------------------

    def _salvage(self, i: int, reason: str) -> None:
        """Replica ``i`` left the routable set: harvest any finished
        outputs its scheduler still holds, abort the rest (slots, pins,
        blocks freed best-effort), and queue every orphaned request for
        a backed-off retry on the survivors."""
        rep = self.replicas[i]
        sched = rep.scheduler
        # finished outputs survive on the gateway record even after the
        # scheduler object is replaced at rejoin
        for (idx, rid), rec in list(self._live.items()):
            if idx == i and rid in sched.done:
                rec.output = sched.output(rid)
                del self._live[(idx, rid)]
        n_inflight = len(sched.active) + len(sched.prefilling)
        n_queued = len(sched.queue)
        states = sched.abort()
        for st in states:
            rec = self._live.pop((i, st.rid), None)
            if rec is None:
                continue       # submitted directly to the scheduler,
            rec.emitted = list(st.emitted)   # not through this gateway
            self._schedule_retry(rec, reason)
        self.failovers += 1
        sched.tracer.failover(rep.name, n_inflight, n_queued, reason)

    def _schedule_retry(self, rec: _GatewayRequest, error: str) -> None:
        rec.attempts += 1
        rec.last_error = error
        if rec.attempts > self.retry.max_retries:
            self._fail(rec, "retry_budget_exhausted")
            return
        ready = self._gstep + self.retry.backoff_steps(rec.attempts)
        self._retry_queue.append((ready, rec))

    def _fail(self, rec: _GatewayRequest, reason: str) -> None:
        idx, rid = rec.current
        rec.failed = RequestFailed(handle=rec.handle, rid=rid,
                                   reason=reason, attempts=rec.attempts,
                                   last_error=rec.last_error)
        self._live.pop(rec.current, None)
        self.replicas[idx].scheduler.tracer.request_failed(
            rid, reason, rec.attempts)

    def _pump_retries(self) -> bool:
        """Re-route every backed-off request whose wait expired.  With
        no routable replica: wait if a quarantined one may still rejoin,
        otherwise fail typed — never spin forever."""
        if not self._retry_queue:
            return False
        due = [(r, rec) for r, rec in self._retry_queue
               if r <= self._gstep]
        if not due:
            return False
        rest = [(r, rec) for r, rec in self._retry_queue
                if r > self._gstep]
        rejoin_possible = (
            self.health_config.auto_rejoin
            and any(m.state == QUARANTINED for m in self.health))
        progressed = False
        for ready, rec in due:
            alive = self._routable()
            if not alive:
                if rejoin_possible:
                    rest.append((ready, rec))   # a rejoin is coming
                    continue
                self._fail(rec, "no_routable_replica")
                progressed = True
                continue
            idx, reason, match_len = self._route(rec.request)
            rep = self.replicas[idx]
            prev_idx = rec.current[0]
            rep.routed += 1
            rid = rep.scheduler.submit(
                rec.request, resume_emitted=rec.emitted or None,
                retry=True, admit_while_draining=True)
            rep.scheduler.tracer.route(rid, rep.name, reason, match_len,
                                       rep.load)
            rep.scheduler.tracer.retry(
                rid, rec.attempts,
                self.retry.backoff_steps(rec.attempts),
                prev_replica=self.replicas[prev_idx].name)
            rec.current = (idx, rid)
            self._live[(idx, rid)] = rec
            progressed = True
        self._retry_queue = rest
        return progressed

    def rejoin(self, i: int) -> None:
        """Relaunch replica ``i``'s capsule.  In-process: a fresh
        scheduler over the *same* engine (the engine-held prefix cache
        survives, so re-routed prompts probe warm), rid numbering
        carried forward so the shared tracer/metrics never see a rid
        collision.  A fabric replica (anything exposing ``respawn``)
        instead cancels its old worker job and submits a fresh one for
        the same spec — the cross-process capsule relaunch."""
        rep = self.replicas[i]
        old = rep.scheduler
        mon = self.health[i]
        try:
            old.abort()        # should be empty post-salvage; make sure
        except Exception:      # noqa: BLE001 — best-effort, like salvage
            pass
        if hasattr(old, "respawn"):
            new = old.respawn(draining=self.draining)
        else:
            # the injector is carried, NOT reset: an exhausted transient
            # fault stays exhausted — the plan's schedule is absolute
            # over the replica's lifetime, so a rejoined replica does
            # not replay the stall that quarantined it
            inj = old.fault_injector
            new = Scheduler(
                old.engine, tracer=old.tracer,
                max_admissions_per_step=old.max_admissions_per_step,
                prefill_token_budget=old.prefill_token_budget,
                profile=old.profiler is not None,
                fault_injector=inj)
            new._next_rid = old._next_rid
            new.done.update(old.done)  # finished outputs stay reachable
            new.draining = self.draining
        rep.scheduler = new
        self._quarantined_at[i] = None
        tr = mon.mark_rejoined()
        rep.scheduler.tracer.replica_health(
            rep.name, str(tr["from"]), str(tr["to"]), str(tr["reason"]),
            int(tr["consecutive_bad"]))  # type: ignore[call-overload]
        kv = new.engine.kv
        pool = getattr(kv, "prefix_pool", None)
        warm = pool.in_use if pool is not None else 0
        rep.scheduler.tracer.rejoin(rep.name, mon.rejoins, warm)

    # -- degradation ladder --------------------------------------------------

    def _update_degradation(self) -> None:
        pol = self.degradation
        if pol is None:
            return
        qd = self._fleet_queue_depth()
        exhausted = ((pol.shed_queue_depth is not None
                      and qd >= pol.shed_queue_depth)
                     or not self._routable())
        breached = bool(self._breached_tenants())
        if breached:
            self._breach_run += 1
        else:
            self._breach_run = 0
        if exhausted or breached:
            self._ok_run = 0
        else:
            self._ok_run += 1
        if not self.degraded and (exhausted
                                  or self._breach_run >= pol.breach_steps):
            self._enter_degraded(
                "queue_exhausted" if exhausted else "slo_breach_sustained",
                qd)
        elif (self.degraded and not exhausted and not breached
                and self._ok_run >= pol.recover_steps):
            self._exit_degraded(qd)

    def _enter_degraded(self, reason: str, queue_depth: int) -> None:
        self.degraded = True
        self.degraded_transitions += 1
        pol = self.degradation
        assert pol is not None
        for i, rep in enumerate(self.replicas):
            b = rep.scheduler.prefill_token_budget
            self._saved_budgets[i] = b
            if b is not None:
                rep.scheduler.prefill_token_budget = max(
                    1, int(b * pol.budget_shrink))
        self.replicas[0].scheduler.tracer.overload(
            True, reason, queue_depth)

    def _exit_degraded(self, queue_depth: int) -> None:
        self.degraded = False
        for i, rep in enumerate(self.replicas):
            if i in self._saved_budgets:
                rep.scheduler.prefill_token_budget = self._saved_budgets[i]
        self._saved_budgets.clear()
        self.replicas[0].scheduler.tracer.overload(
            False, "recovered", queue_depth)

    # -- run / drain ---------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return (any(self.health[i].routable and r.scheduler.has_work
                    for i, r in enumerate(self.replicas))
                or bool(self._retry_queue)
                or (self.health_config.auto_rejoin
                    and any(m.state == QUARANTINED for m in self.health)
                    and any(r.scheduler.has_work for r in self.replicas)))

    def run(self) -> None:
        """Run until no routable replica has work and no retry is
        pending.  A fleet that makes zero observable progress for
        ``stall_patience`` consecutive steps raises instead of spinning
        — the drain-hang fix: quarantine normally resolves a wedged
        replica well before the watchdog trips, so hitting it means
        health thresholds are misconfigured or every replica is wedged
        below detection."""
        stagnant = 0
        while self.has_work:
            if self.step():
                stagnant = 0
                continue
            stagnant += 1
            if stagnant >= self.stall_patience:
                wedged = [self.replicas[i].name
                          for i, m in enumerate(self.health)
                          if m.routable
                          and self.replicas[i].scheduler.has_work]
                raise RuntimeError(
                    f"gateway made no progress for {stagnant} consecutive "
                    f"steps with work pending (replicas with stuck work: "
                    f"{wedged or 'none — retries cannot route'}); a "
                    f"wedged replica should have been quarantined — "
                    f"check HealthConfig thresholds vs stall_patience")

    def drain(self) -> None:
        """Graceful drain: no new admissions; every in-flight request
        either completes (possibly on another replica after failover)
        or resolves to a typed :class:`RequestFailed`."""
        self.draining = True
        for rep in self.replicas:
            rep.scheduler.draining = True
        self.run()
        # every record must resolve: harvest stragglers, fail the rest
        # loudly (a lost request must never be a silent hang for its
        # caller)
        for rec in self._requests.values():
            if rec.output is not None or rec.failed is not None:
                continue
            idx, rid = rec.current
            sched = self.replicas[idx].scheduler
            if rid in sched.done:
                rec.output = sched.output(rid)
                self._live.pop(rec.current, None)
            else:
                self._fail(rec, "lost_at_drain")

    # -- results / telemetry -------------------------------------------------

    def result(self, handle: Tuple[int, int]):
        """Resolve a handle from :meth:`submit`: the output tokens
        (np.ndarray) or a typed :class:`RequestFailed`.  Raises KeyError
        for a handle this gateway never issued and RuntimeError for a
        request that has not finished yet."""
        try:
            key = (int(handle[0]), int(handle[1]))
        except (TypeError, ValueError, IndexError):
            raise KeyError(f"malformed request handle {handle!r}: "
                           f"expected a (replica, rid) pair") from None
        rec = self._requests.get(key)
        if rec is None:
            raise KeyError(
                f"unknown request handle {key!r}: not issued by this "
                f"gateway's submit()")
        if rec.failed is not None:
            return rec.failed
        if rec.output is None:
            idx, rid = rec.current
            sched = self.replicas[idx].scheduler
            if rid not in sched.done:
                raise RuntimeError(
                    f"request {key!r} has not finished (now rid {rid} on "
                    f"{self.replicas[idx].name}, attempt "
                    f"{rec.attempts + 1}); step or drain the gateway")
            rec.output = sched.output(rid)
            self._live.pop(rec.current, None)
        return rec.output

    def stats(self) -> Dict[str, Any]:
        summaries = [rep.scheduler.metrics.summary() for rep in self.replicas]
        per = {}
        for rep, s in zip(self.replicas, summaries):
            entry = {**s, "routed": rep.routed, "capsule": rep.capsule}
            if rep.scheduler.tracer.slo is not None:
                entry["slo"] = rep.scheduler.tracer.slo.summary()
            if rep.scheduler.profiler is not None:
                entry["profile"] = rep.scheduler.profiler.summary()
            per[rep.name] = entry
        totals = merge_summaries(summaries)
        breaches = sum(p["slo"]["breaches"] for p in per.values()
                       if "slo" in p)
        if any("slo" in p for p in per.values()):
            totals["slo_breaches"] = breaches
        fleet = {
            "health": {rep.name: mon.summary()
                       for rep, mon in zip(self.replicas, self.health)},
            "failovers": self.failovers,
            "requests_failed": sum(1 for r in self._requests.values()
                                   if r.failed is not None),
            "requests_retried": sum(1 for r in self._requests.values()
                                    if r.attempts > 0),
            "retries_pending": len(self._retry_queue),
            "shed_requests": self.shed_requests,
            "capped_requests": self.capped_requests,
            "degraded": self.degraded,
            "degraded_transitions": self.degraded_transitions,
        }
        return {"replicas": per, "totals": totals, "fleet": fleet}

    # -- tracing -------------------------------------------------------------

    @property
    def tracers(self) -> List[Tracer]:
        return [rep.scheduler.tracer for rep in self.replicas]

    def trace_events(self) -> List[Dict[str, Any]]:
        """The fleet's merged timeline: every replica's ring buffer
        interleaved on the shared clock, replica-stamped."""
        return merge_traces(self.tracers)

    def export_trace_jsonl(self, path):
        """Merged JSONL event log (one JSON object per line)."""
        return export_jsonl(self.trace_events(), path)

    def export_chrome_trace(self, path):
        """Chrome trace-event file: replicas as processes, request spans
        as async lanes — loads directly in Perfetto/chrome://tracing."""
        return export_chrome_trace(
            {rep.name: rep.scheduler.tracer.snapshot()
             for rep in self.replicas}, path)


def launch_capsule_replicas(
        n: int, engine_factory: Callable[[], ServingEngine], work_dir,
        image_definition=None) -> Tuple[ReplicaGateway, Any]:
    """Deploy one immutable image and launch ``n`` serving replicas from
    it, each engine constructed inside a ``CapsuleRuntime.run`` (the
    ``ch-run`` analogue) so the launch bookkeeping — image hash, uid map,
    scrubbed env — is recorded per replica.  Returns (gateway, deployment).
    """
    from repro.core import deploy as D

    if n <= 0:
        raise ValueError(f"need at least one replica, got n={n}")
    if not callable(engine_factory):
        raise TypeError(
            f"engine_factory must be callable, got "
            f"{type(engine_factory).__name__}")
    pipe = D.DeploymentPipeline()
    definition = image_definition or D.intel_tensorflow_image(
        "serving-replica")
    dep = pipe.deploy(definition, Path(work_dir))
    replicas = []
    for r in range(n):
        res = dep.run(engine_factory, ranks=1)[0]
        replicas.append(CapsuleReplica(
            f"replica{r}", Scheduler(res.value),
            capsule={"image": res.image, "uid_map": res.uid_map,
                     "env": res.env, "wall_time_s": res.wall_time_s}))
    return ReplicaGateway(replicas), dep
