"""Multi-replica serving gateway: least-loaded dispatch + graceful drain.

Scale-out layer of the serving story.  Each replica is one
:class:`~repro.serving.scheduler.Scheduler` over one engine — conceptually
one ``ch-run`` capsule instance of the same immutable image, the way the
paper's deployment runs one containerized process per allocation.  The
gateway front-ends N replicas:

* ``submit`` routes with *prefix affinity*: the request goes to the
  replica whose prefix cache holds the longest prefix of its prompt
  (ties and misses broken by least load).  When no replica has the
  prefix yet, the first block of token ids is hashed to pick a stable
  owner — so every request opening with the same system prompt lands on
  the same capsule and warms a single cache instead of N — unless that
  owner is overloaded by more than ``affinity_slack`` requests relative
  to the least-loaded replica, in which case load wins;
* ``step`` advances every replica one decode round (single-host stand-in
  for replicas running concurrently on their own nodes);
* ``drain`` closes admission and runs every replica until all in-flight
  requests complete — the graceful-shutdown path a rolling image update
  needs (the capsule is immutable, so an update is drain + relaunch).

``launch_capsule_replicas`` builds the engines *inside* ``ch-run``
launches via :class:`~repro.core.container.CapsuleRuntime`, recording the
per-replica capsule bookkeeping (image, uid map, scrubbed env) on the
handle; unit tests may also construct replicas from bare engines.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import merge_summaries
from repro.serving.scheduler import Scheduler
from repro.serving.tracing import (Tracer, export_jsonl,
                                   export_chrome_trace, merge_traces)


@dataclass
class CapsuleReplica:
    """One serving replica + its launch bookkeeping."""
    name: str
    scheduler: Scheduler
    capsule: Optional[Dict[str, Any]] = None   # image/uid_map/env of ch-run
    routed: int = 0

    @property
    def load(self) -> int:
        return self.scheduler.load


class ReplicaGateway:
    """Prefix-affine, load-balanced request router over N replicas."""

    def __init__(self, replicas: List[CapsuleReplica],
                 affinity_slack: int = 2):
        assert replicas, "gateway needs at least one replica"
        self.replicas = replicas
        self.affinity_slack = affinity_slack
        self.draining = False

    @classmethod
    def from_engines(cls, engines: List[ServingEngine], *,
                     affinity_slack: int = 2, tracing: bool = False,
                     trace_buffer_events: Optional[int] = None,
                     slo_config=None,
                     **sched_kw) -> "ReplicaGateway":
        """``tracing=True`` gives every replica an enabled
        :class:`~repro.serving.tracing.Tracer` (ring depth
        ``trace_buffer_events``) on the shared process clock, so
        :meth:`trace_events` can interleave the fleet's buffers into one
        timeline.  ``slo_config`` (an
        :class:`~repro.serving.slo.SLOConfig`) arms every replica's
        tracer with its own :class:`~repro.serving.slo.SLOMonitor` —
        breach state is per replica, the policies are shared."""
        def sched(i, e):
            kw = dict(sched_kw)
            if "tracer" not in kw:
                tkw = {"enabled": tracing, "name": f"replica{i}"}
                if trace_buffer_events is not None:
                    tkw["buffer_events"] = trace_buffer_events
                if slo_config is not None:
                    from repro.serving.slo import SLOMonitor
                    tkw["slo"] = SLOMonitor(slo_config)
                kw["tracer"] = Tracer(**tkw)
            return Scheduler(e, **kw)

        return cls([CapsuleReplica(f"replica{i}", sched(i, e))
                    for i, e in enumerate(engines)],
                   affinity_slack=affinity_slack)

    # -- routing -------------------------------------------------------------

    def _least_loaded(self) -> int:
        return min(range(len(self.replicas)),
                   key=lambda i: (self.replicas[i].load, i))

    def _route(self, request: Request) -> Tuple[int, str, int]:
        """Prefix affinity first, hash ownership second, load third.
        Returns ``(replica index, reason, prefix match length)`` so the
        decision is traceable, not just its outcome."""
        floor = min(rep.load for rep in self.replicas)
        matches = [rep.scheduler.prefix_match_len(request.prompt)
                   for rep in self.replicas]
        best = max(matches)
        if best > 0:
            idx = min((i for i, m in enumerate(matches) if m == best),
                      key=lambda i: (self.replicas[i].load, i))
            # a warm cache is not worth unbounded queueing: same slack
            # rule as hash ownership
            if self.replicas[idx].load <= floor + self.affinity_slack:
                return idx, "prefix_affinity", best
        caching = [i for i, rep in enumerate(self.replicas)
                   if rep.scheduler.prefix_cache is not None]
        if caching and len(request.prompt) > 0:
            # stable owner for a not-yet-cached prefix: hash the first
            # KV block's worth of token ids
            kv = self.replicas[caching[0]].scheduler.engine.kv
            head = np.asarray(request.prompt[:kv.block_size], np.int32)
            owner = caching[zlib.crc32(head.tobytes()) % len(caching)]
            if self.replicas[owner].load <= floor + self.affinity_slack:
                return owner, "hash_owner", best
        return self._least_loaded(), "least_loaded", best

    def submit(self, request: Request) -> Tuple[int, int]:
        """Route with prefix affinity / least load; returns a
        (replica, rid) handle usable with :meth:`result`."""
        if self.draining:
            raise RuntimeError("gateway is draining; admission closed")
        idx, reason, match_len = self._route(request)
        rep = self.replicas[idx]
        rep.routed += 1
        rid = rep.scheduler.submit(request)
        rep.scheduler.tracer.route(rid, rep.name, reason, match_len,
                                   rep.load)
        return idx, rid

    # -- progress ------------------------------------------------------------

    def step(self) -> bool:
        """One decode round on every replica with work."""
        progressed = False
        for rep in self.replicas:
            if rep.scheduler.has_work:
                progressed = rep.scheduler.step() or progressed
        return progressed

    @property
    def has_work(self) -> bool:
        return any(r.scheduler.has_work for r in self.replicas)

    def run(self) -> None:
        while self.has_work:
            self.step()

    def drain(self) -> None:
        """Graceful drain: no new admissions, all in-flight complete."""
        self.draining = True
        for rep in self.replicas:
            rep.scheduler.draining = True
        self.run()

    # -- results / telemetry -------------------------------------------------

    def result(self, handle: Tuple[int, int]) -> np.ndarray:
        idx, rid = handle
        return self.replicas[idx].scheduler.output(rid)

    def stats(self) -> Dict[str, Any]:
        summaries = [rep.scheduler.metrics.summary() for rep in self.replicas]
        per = {}
        for rep, s in zip(self.replicas, summaries):
            entry = {**s, "routed": rep.routed, "capsule": rep.capsule}
            if rep.scheduler.tracer.slo is not None:
                entry["slo"] = rep.scheduler.tracer.slo.summary()
            if rep.scheduler.profiler is not None:
                entry["profile"] = rep.scheduler.profiler.summary()
            per[rep.name] = entry
        totals = merge_summaries(summaries)
        breaches = sum(p["slo"]["breaches"] for p in per.values()
                       if "slo" in p)
        if any("slo" in p for p in per.values()):
            totals["slo_breaches"] = breaches
        return {"replicas": per, "totals": totals}

    # -- tracing -------------------------------------------------------------

    @property
    def tracers(self) -> List[Tracer]:
        return [rep.scheduler.tracer for rep in self.replicas]

    def trace_events(self) -> List[Dict[str, Any]]:
        """The fleet's merged timeline: every replica's ring buffer
        interleaved on the shared clock, replica-stamped."""
        return merge_traces(self.tracers)

    def export_trace_jsonl(self, path):
        """Merged JSONL event log (one JSON object per line)."""
        return export_jsonl(self.trace_events(), path)

    def export_chrome_trace(self, path):
        """Chrome trace-event file: replicas as processes, request spans
        as async lanes — loads directly in Perfetto/chrome://tracing."""
        return export_chrome_trace(
            {rep.name: rep.scheduler.tracer.snapshot()
             for rep in self.replicas}, path)


def launch_capsule_replicas(
        n: int, engine_factory: Callable[[], ServingEngine], work_dir,
        image_definition=None) -> Tuple[ReplicaGateway, Any]:
    """Deploy one immutable image and launch ``n`` serving replicas from
    it, each engine constructed inside a ``CapsuleRuntime.run`` (the
    ``ch-run`` analogue) so the launch bookkeeping — image hash, uid map,
    scrubbed env — is recorded per replica.  Returns (gateway, deployment).
    """
    from repro.core import deploy as D

    pipe = D.DeploymentPipeline()
    definition = image_definition or D.intel_tensorflow_image(
        "serving-replica")
    dep = pipe.deploy(definition, Path(work_dir))
    replicas = []
    for r in range(n):
        res = dep.run(engine_factory, ranks=1)[0]
        replicas.append(CapsuleReplica(
            f"replica{r}", Scheduler(res.value),
            capsule={"image": res.image, "uid_map": res.uid_map,
                     "env": res.env, "wall_time_s": res.wall_time_s}))
    return ReplicaGateway(replicas), dep
