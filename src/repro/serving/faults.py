"""Deterministic fault injection for the serving fleet.

The source paper's deployments are long-lived containerized jobs on a
batch-scheduled HPC system: nodes fail, allocations get preempted, a
capsule wedges without exiting.  Testing the gateway's failure handling
against *real* failures is neither deterministic nor CI-friendly, so
this module provides the seeded stand-in: a :class:`FaultPlan` describes
*what goes wrong where and when*, and per-replica :class:`FaultInjector`
instances replay it — bit-identically across runs — through explicit
hooks in :class:`~repro.serving.scheduler.Scheduler` (``step()``) and
:class:`~repro.serving.engine.ServingEngine` (``advance_prefill`` /
``decode_once``).

Fault kinds (``FaultSpec.kind``):

``raise``
    The hook raises :class:`InjectedFault` (a transient error) for
    ``duration`` consecutive firings.  The scheduler's existing error
    paths requeue any in-flight work, so a transient raise costs retries
    but never loses a request.
``stall``
    ``Scheduler.step()`` reports progress (returns True) while doing
    *nothing* for ``duration`` steps — the wedged-capsule shape that
    return-value-based liveness checks cannot see.  Only the gateway's
    progress-signature watchdog catches it.
``crash``
    Permanent: the hook raises :class:`ReplicaCrashed` on this and every
    later firing (``reset()`` after a capsule relaunch clears it).  The
    gateway marks the replica DEAD and fails over.
``slow``
    The hook sleeps ``latency_s`` per firing for ``duration`` firings —
    the degraded-node shape that trips SLO breaches, not health checks.

Scheduling is by replica-local step index (``at_step``) and/or a
per-firing ``probability`` drawn from a deterministic per-replica
stream, so a whole fleet's fault schedule replays identically from one
``FaultPlan(seed=...)``.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("raise", "stall", "crash", "slow")
FAULT_SITES = ("step", "prefill", "decode")


class InjectedFault(RuntimeError):
    """A transient injected failure (the replica can recover)."""


class ReplicaCrashed(RuntimeError):
    """A permanent injected failure: every later hook firing raises
    again, like a process that died — only ``FaultInjector.reset()``
    (the capsule-relaunch analogue) brings the replica back."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``replica`` is a name or ``"*"`` (all);
    the fault arms at replica-local step ``at_step`` (None = armed from
    step 0) and, once armed, fires with ``probability`` per step (1.0 =
    fire deterministically the step it arms)."""
    kind: str
    replica: str = "*"
    at_step: Optional[int] = None
    probability: float = 1.0
    duration: int = 1                  # firings (ignored by crash)
    latency_s: float = 0.0             # slow only
    site: str = "step"                 # step | prefill | decode

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {FAULT_SITES}")
        if self.kind in ("stall", "slow") and self.site != "step":
            raise ValueError(f"{self.kind} faults only make sense at "
                             f"site='step' (got {self.site!r})")
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError(f"probability must be in [0, 1], "
                             f"got {self.probability}")
        if self.duration <= 0:
            raise ValueError(f"duration must be positive, "
                             f"got {self.duration}")
        if self.kind == "slow" and self.latency_s <= 0.0:
            raise ValueError("slow faults need latency_s > 0")


@dataclass
class FaultPlan:
    """A seeded fleet-wide fault schedule.  One plan hands out one
    :class:`FaultInjector` per replica (``injector_for``); two plans
    with equal specs and seed replay identical schedules."""
    specs: Sequence[FaultSpec] = field(default_factory=tuple)
    seed: int = 0

    def injector_for(self, replica: str) -> "FaultInjector":
        mine = [s for s in self.specs
                if s.replica in ("*", replica)]
        return FaultInjector(mine, seed=self.seed, replica=replica)

    @classmethod
    def random(cls, seed: int, replicas: Sequence[str], n_faults: int = 3,
               max_step: int = 20,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """A randomized-but-deterministic plan for chaos harnesses:
        ``n_faults`` specs drawn over ``replicas``, armed within
        ``max_step`` replica-local steps."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            kind = str(rng.choice(list(kinds)))
            site = "step"
            if kind in ("raise", "crash"):
                site = str(rng.choice(FAULT_SITES))
            specs.append(FaultSpec(
                kind=kind,
                replica=str(rng.choice(list(replicas))),
                at_step=int(rng.integers(1, max_step)),
                duration=int(rng.integers(1, 4)),
                latency_s=1e-3 if kind == "slow" else 0.0,
                site=site))
        return cls(tuple(specs), seed=seed)


class FaultInjector:
    """Per-replica replay of a :class:`FaultPlan` slice.

    The scheduler calls :meth:`on_step` at the top of every ``step()``;
    the engine calls :meth:`on_engine_op` at the top of
    ``advance_prefill`` / ``decode_once``.  Both either return/no-op,
    sleep (slow), or raise (:class:`InjectedFault` /
    :class:`ReplicaCrashed`).  The probability stream is seeded from
    ``(plan seed, replica name)`` so schedules are independent across
    replicas yet fully reproducible.
    """

    def __init__(self, specs: Sequence[FaultSpec], *, seed: int = 0,
                 replica: str = "replica0", sleep=time.sleep):
        self.specs = list(specs)
        self.seed = seed
        self.replica = replica
        self._sleep = sleep
        self.fired: List[Tuple[int, str, str]] = []   # (step, kind, site)
        self.reset()

    def reset(self) -> "FaultInjector":
        """Capsule-relaunch analogue: clears the crashed flag and all
        firing windows, restarts the step index and the probability
        stream (the relaunched process replays its schedule afresh)."""
        self.step_index = 0
        self.crashed = False
        self._rng = np.random.default_rng(
            (self.seed << 16) ^ zlib.crc32(self.replica.encode()))
        self._remaining = [s.duration for s in self.specs]
        return self

    # -- firing logic --------------------------------------------------------

    def _fire(self, spec: FaultSpec, i: int, step: int, site: str) -> str:
        self._remaining[i] -= 1
        self.fired.append((step, spec.kind, site))
        if spec.kind == "crash":
            self.crashed = True
            raise ReplicaCrashed(
                f"{self.replica}: injected crash at step {step} ({site})")
        if spec.kind == "raise":
            raise InjectedFault(
                f"{self.replica}: injected transient fault at step "
                f"{step} ({site})")
        if spec.kind == "slow":
            self._sleep(spec.latency_s)
            return "ok"
        return "stall"

    def _scan(self, step: int, site: str) -> str:
        outcome = "ok"
        for i, spec in enumerate(self.specs):
            if spec.site != site or self._remaining[i] <= 0:
                continue
            if spec.at_step is not None and step < spec.at_step:
                continue
            if (spec.probability < 1.0
                    and float(self._rng.random()) >= spec.probability):
                continue
            if self._fire(spec, i, step, site) == "stall":
                outcome = "stall"
        return outcome

    def on_step(self) -> str:
        """Scheduler hook.  Returns ``"stall"`` (the scheduler must
        return True without touching any state) or ``"ok"``; raises for
        raise/crash faults.  Advances the replica-local step index —
        even when the step raises, so a transient fault is not replayed
        forever against the same step."""
        if self.crashed:
            raise ReplicaCrashed(
                f"{self.replica}: capsule is down (crashed earlier)")
        step = self.step_index
        self.step_index += 1
        return self._scan(step, "step")

    def on_engine_op(self, site: str) -> None:
        """Engine hook (``site`` is ``"prefill"`` or ``"decode"``);
        raises for raise/crash faults scheduled at that site."""
        if self.crashed:
            raise ReplicaCrashed(
                f"{self.replica}: capsule is down (crashed earlier)")
        self._scan(self.step_index, site)
