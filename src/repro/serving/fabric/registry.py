"""Cluster registry: what the fabric may submit to, validated up front.

Mirrors the shape production container-launch stacks use: the site's
partitions and their node counts are declared once, and every submit is
validated against remaining capacity *before* anything is rendered or
spawned — a job that can never schedule should fail at the gateway, not
sit PENDING forever in a queue the operator has to go inspect.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


class CapacityError(ValueError):
    """Submit refused at validation: unknown partition or not enough
    free nodes.  Raised before any job state exists."""


@dataclass(frozen=True)
class Partition:
    name: str
    nodes: int
    cores_per_node: int = 48          # SuperMUC-NG thin node

    def __post_init__(self):
        if self.nodes <= 0:
            raise ValueError(f"partition {self.name!r}: nodes must be "
                             f"positive, got {self.nodes}")
        if self.cores_per_node <= 0:
            raise ValueError(f"partition {self.name!r}: cores_per_node "
                             f"must be positive, got {self.cores_per_node}")


@dataclass
class ClusterRegistry:
    """Partitions and their committed-node bookkeeping."""
    partitions: Dict[str, Partition] = field(default_factory=dict)
    committed: Dict[str, int] = field(default_factory=dict)

    @classmethod
    def single_partition(cls, name: str = "general", nodes: int = 8,
                         cores_per_node: int = 48) -> "ClusterRegistry":
        reg = cls()
        reg.add(Partition(name, nodes, cores_per_node))
        return reg

    def add(self, partition: Partition) -> None:
        self.partitions[partition.name] = partition
        self.committed.setdefault(partition.name, 0)

    def free_nodes(self, partition: str) -> int:
        part = self.partitions.get(partition)
        if part is None:
            raise CapacityError(
                f"unknown partition {partition!r}; registered: "
                f"{sorted(self.partitions) or 'none'}")
        return part.nodes - self.committed[partition]

    def validate(self, partition: str, nodes: int = 1) -> None:
        """Refuse a submit that cannot fit.  Raises CapacityError."""
        if nodes <= 0:
            raise CapacityError(f"nodes must be positive, got {nodes}")
        free = self.free_nodes(partition)
        if nodes > free:
            raise CapacityError(
                f"partition {partition!r}: requested {nodes} node(s), "
                f"{free} free of {self.partitions[partition].nodes}")

    def commit(self, partition: str, nodes: int = 1) -> None:
        self.validate(partition, nodes)
        self.committed[partition] += nodes

    def release(self, partition: str, nodes: int = 1) -> None:
        self.committed[partition] = max(
            0, self.committed.get(partition, 0) - nodes)

    def summary(self) -> List[Dict[str, int]]:
        return [{"partition": p.name, "nodes": p.nodes,
                 "committed": self.committed[p.name],
                 "free": p.nodes - self.committed[p.name]}
                for p in self.partitions.values()]
