"""Pluggable scheduler backends: how the fabric launches replica workers.

One abstraction, three adapters, mirroring how production
container-on-HPC stacks separate *what* to launch from *who* launches
it:

* :class:`SlurmBackend` — renders a real sbatch script through
  :func:`repro.launch.slurm.render_script` (the paper's submission
  pattern: ``ch-run`` inside an exclusive allocation) into the spool's
  ``jobs/`` directory and tracks the job lifecycle
  PENDING -> RUNNING -> COMPLETED / FAILED off the worker's heartbeat
  and status files — the only signals an air-gapped login node gets.
* :class:`LocalProcessBackend` — real ``subprocess`` workers on this
  host: the integration path (kill one mid-burst and watch failover).
* :class:`MockBackend` — drives :class:`~repro.serving.fabric.worker.
  ReplicaWorker` objects in-process and deterministically, so the whole
  fabric (mailbox included, byte for byte the same code) is testable
  hermetically.

Every submit validates against the :class:`~repro.serving.fabric.
registry.ClusterRegistry` *before* any job state exists
(validate-before-submit), and terminal jobs release their nodes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.serving.fabric.mailbox import Mailbox
from repro.serving.fabric.registry import ClusterRegistry
from repro.serving.fabric.worker import ReplicaWorker, spec_to_args

PENDING = "PENDING"
RUNNING = "RUNNING"
COMPLETED = "COMPLETED"
FAILED = "FAILED"


@dataclass(frozen=True)
class WorkerSpec:
    """Everything a backend needs to launch one replica worker."""
    replica: str
    spool: Path
    model_spec: Optional[Dict[str, Any]] = None
    image_dir: Optional[str] = None
    partition: str = "general"
    nodes: int = 1
    threads_per_rank: int = 2
    walltime: str = "08:00:00"


@dataclass
class JobHandle:
    """One submitted worker job.  ``state`` is backend-maintained; the
    gateway proxy only ever reads it through :meth:`SchedulerBackend.
    poll`."""
    job_id: str
    spec: WorkerSpec
    state: str = PENDING
    error: str = ""
    _released: bool = field(default=False, repr=False)


class SchedulerBackend(ABC):
    """ABC every adapter implements.  ``synchronous`` marks backends
    whose workers only progress inside :meth:`poll` (the mock) — the
    gateway proxy then skips its wall-clock wait loop."""

    synchronous = False

    def __init__(self, registry: Optional[ClusterRegistry] = None):
        self.registry = registry or ClusterRegistry.single_partition()
        self.jobs: List[JobHandle] = []
        self._next_job = 0

    def submit(self, spec: WorkerSpec) -> JobHandle:
        """Validate capacity, then launch.  CapacityError propagates
        before any job exists; a launch failure releases the nodes."""
        self.registry.commit(spec.partition, spec.nodes)
        self._next_job += 1
        handle = JobHandle(job_id=f"{self._next_job}", spec=spec)
        try:
            self._launch(handle)
        except Exception:
            self.registry.release(spec.partition, spec.nodes)
            raise
        self.jobs.append(handle)
        return handle

    def _release(self, handle: JobHandle) -> None:
        if not handle._released:
            handle._released = True
            self.registry.release(handle.spec.partition,
                                  handle.spec.nodes)

    @abstractmethod
    def _launch(self, handle: JobHandle) -> None:
        """Start the worker for ``handle`` (state stays PENDING until
        poll observes it running)."""

    @abstractmethod
    def poll(self, handle: JobHandle) -> str:
        """Current lifecycle state; releases nodes on terminal states."""

    @abstractmethod
    def cancel(self, handle: JobHandle) -> None:
        """Hard-stop the job (scancel / SIGKILL analogue)."""

    # -- shared status-file plumbing -----------------------------------------

    @staticmethod
    def _read_status(spec: WorkerSpec) -> Optional[Dict[str, Any]]:
        path = Path(spec.spool) / spec.replica / "status.json"
        try:
            return json.loads(path.read_text())
        except (OSError, ValueError):
            return None


class MockBackend(SchedulerBackend):
    """Deterministic in-process adapter: each "job" is a real
    :class:`ReplicaWorker` advanced ``iterations_per_poll`` pumps every
    time the gateway polls it — no wall clock, no processes, the exact
    mailbox/worker code the subprocess path runs.

    ``engine_factory`` (replica name -> engine) lets tests share model
    params across workers; without it each worker builds from its model
    spec.  ``fault_plan`` wires a
    :class:`~repro.serving.faults.FaultInjector` into every worker's
    scheduler + engine, extending the PR 9 chaos harness across the
    (simulated) process boundary."""

    synchronous = True

    def __init__(self, registry: Optional[ClusterRegistry] = None, *,
                 engine_factory=None, fault_plan=None,
                 iterations_per_poll: int = 1):
        super().__init__(registry)
        self.engine_factory = engine_factory
        self.fault_plan = fault_plan
        self.iterations_per_poll = iterations_per_poll
        self.workers: Dict[str, ReplicaWorker] = {}
        self._stalled: set = set()

    def _launch(self, handle: JobHandle) -> None:
        spec = handle.spec
        engine = (self.engine_factory(spec.replica)
                  if self.engine_factory is not None else None)
        worker = ReplicaWorker(spec.spool, spec.replica, engine=engine,
                               model_spec=spec.model_spec)
        if self.fault_plan is not None:
            inj = self.fault_plan.injector_for(spec.replica)
            worker.sched.fault_injector = inj
            worker.sched.engine.fault_injector = inj
        self.workers[handle.job_id] = worker

    def stall(self, handle: JobHandle) -> None:
        """Wedge the worker: it stays RUNNING but stops iterating, so
        its heartbeat seq freezes — the stale-heartbeat failure mode
        (a hung process, a dead filesystem client) as a chaos lever."""
        self._stalled.add(handle.job_id)

    def resume(self, handle: JobHandle) -> None:
        self._stalled.discard(handle.job_id)

    def poll(self, handle: JobHandle) -> str:
        if handle.state in (COMPLETED, FAILED):
            return handle.state
        if handle.job_id in self._stalled:
            return handle.state
        worker = self.workers[handle.job_id]
        for _ in range(self.iterations_per_poll):
            if worker.finished:
                break
            try:
                worker.iterate()
            except Exception as e:  # noqa: BLE001 — the worker crashed
                worker.fail(e)
                handle.state = FAILED
                handle.error = repr(e)
                self._release(handle)
                return handle.state
        if worker.finished:
            status = self._read_status(handle.spec) or {}
            failed = status.get("state") == "failed"
            handle.state = FAILED if failed else COMPLETED
            handle.error = status.get("error", "")
            self._release(handle)
        else:
            handle.state = RUNNING
        return handle.state

    def cancel(self, handle: JobHandle) -> None:
        worker = self.workers.get(handle.job_id)
        if worker is not None and not worker.finished:
            worker.stopped = True
            worker.finished = True     # hard kill: no status, no trace
        if handle.state not in (COMPLETED, FAILED):
            handle.state = FAILED
            handle.error = handle.error or "cancelled"
        self._release(handle)

    def kill(self, handle: JobHandle) -> None:
        """Crash simulation: the worker dies mid-flight — heartbeats
        simply stop, exactly like a SIGKILLed process."""
        self.cancel(handle)


class LocalProcessBackend(SchedulerBackend):
    """Real subprocess workers: ``python -m repro.serving.fabric.worker``
    per replica, talking through the same spool.  The integration
    backend — kill(-9)able, genuinely concurrent."""

    def __init__(self, registry: Optional[ClusterRegistry] = None):
        super().__init__(registry)
        self.procs: Dict[str, subprocess.Popen] = {}

    def _worker_env(self) -> Dict[str, str]:
        import repro
        # namespace-package safe: __path__ always exists, __file__ may
        # be None
        src = str(Path(list(repro.__path__)[0]).resolve().parent)
        env = dict(os.environ)
        prev = env.get("PYTHONPATH")
        env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
        return env

    def _launch(self, handle: JobHandle) -> None:
        spec = handle.spec
        argv = [sys.executable] + spec_to_args(
            spec.spool, spec.replica, spec.model_spec, spec.image_dir)
        self.procs[handle.job_id] = subprocess.Popen(
            argv, env=self._worker_env(),
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    def poll(self, handle: JobHandle) -> str:
        if handle.state in (COMPLETED, FAILED):
            return handle.state
        proc = self.procs[handle.job_id]
        rc = proc.poll()
        if rc is None:
            mb = Mailbox(handle.spec.spool, handle.spec.replica)
            if handle.state == PENDING and mb.heartbeat_path.exists():
                handle.state = RUNNING
            return handle.state
        if rc == 0:
            status = self._read_status(handle.spec) or {}
            failed = status.get("state") == "failed"
            handle.state = FAILED if failed else COMPLETED
            handle.error = status.get("error", "")
        else:
            handle.state = FAILED
            handle.error = f"worker exited with code {rc}"
        self._release(handle)
        return handle.state

    def cancel(self, handle: JobHandle) -> None:
        proc = self.procs.get(handle.job_id)
        if proc is not None and proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
        if handle.state not in (COMPLETED, FAILED):
            handle.state = FAILED
            handle.error = handle.error or "cancelled"
        self._release(handle)

    def kill(self, handle: JobHandle) -> None:
        """SIGKILL the worker — the chaos lever the fabric benchmark
        pulls mid-burst."""
        self.cancel(handle)


class SlurmBackend(SchedulerBackend):
    """Renders and "submits" sbatch scripts.  On a real cluster the
    rendered script is what ``sbatch`` consumes; here submission means
    the script lands in ``spool/jobs/`` with a job id, and the
    lifecycle is tracked off the worker's spool signals: heartbeat
    appears -> RUNNING, status file -> COMPLETED / FAILED.  That is
    also exactly what a login-node poller can observe on an air-gapped
    system where ``squeue`` is the only other window."""

    def __init__(self, registry: Optional[ClusterRegistry] = None):
        super().__init__(registry)
        self.scripts: Dict[str, Path] = {}

    def _launch(self, handle: JobHandle) -> None:
        import shlex

        from repro.launch import slurm
        spec = handle.spec
        argv = spec_to_args(spec.spool, spec.replica, spec.model_spec,
                            spec.image_dir)
        # the model spec is a JSON blob — every arg must survive the
        # shell line the template interpolates it into
        script = slurm.render_script(
            job_name=f"fabric-{spec.replica}",
            image_dir=spec.image_dir or "/tmp/capsules/serving",
            entrypoint="python", nodes=spec.nodes,
            threads_per_rank=spec.threads_per_rank,
            walltime=spec.walltime, partition=spec.partition,
            script=" ".join(shlex.quote(a) for a in argv),
            env={"REPRO_FABRIC_SPOOL": str(spec.spool),
                 "REPRO_FABRIC_REPLICA": spec.replica})
        jobs = Path(spec.spool) / "jobs"
        jobs.mkdir(parents=True, exist_ok=True)
        path = jobs / f"{handle.job_id}-{spec.replica}.sbatch"
        path.write_text(script)
        self.scripts[handle.job_id] = path

    def poll(self, handle: JobHandle) -> str:
        if handle.state in (COMPLETED, FAILED):
            return handle.state
        status = self._read_status(handle.spec)
        if status is not None:
            handle.state = (FAILED if status.get("state") == "failed"
                            else COMPLETED)
            handle.error = status.get("error", "")
            self._release(handle)
            return handle.state
        mb = Mailbox(handle.spec.spool, handle.spec.replica)
        if mb.heartbeat_path.exists():
            handle.state = RUNNING
        return handle.state

    def cancel(self, handle: JobHandle) -> None:
        if handle.state not in (COMPLETED, FAILED):
            handle.state = FAILED        # scancel analogue
            handle.error = handle.error or "cancelled"
        self._release(handle)
