"""Replica worker: one ``Scheduler`` + ``ServingEngine`` behind a mailbox.

This is what a fabric backend launches — as a real subprocess
(``python -m repro.serving.fabric.worker``, the ``LocalProcessBackend``
path and the payload of a rendered sbatch script), or as an in-process
object the ``MockBackend`` drives deterministically.  Either way the
code path is identical: consume submit/drain/stop messages from the
inbox, advance the scheduler, publish results to the outbox, and write
a monotonically-sequenced heartbeat carrying the progress counters the
gateway's health ladder feeds on plus the emitted-so-far tokens that
make cross-process salvage-resume bit-identical.

The subprocess path runs the serve loop inside
:meth:`repro.core.container.CapsuleRuntime.run` when an unpacked image
directory is supplied — the paper's shape: every replica is one
unprivileged ``ch-run`` capsule of the same immutable image, launched
by the batch scheduler.

The engine is rebuilt from a declarative *model spec* (smoke-config
name + PRNG seed + engine kwargs): parameter init is deterministic, so
every worker process holds bit-identical weights and greedy outputs
match across process boundaries.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving.engine import Request, SamplingParams, ServingEngine
from repro.serving.fabric.mailbox import Mailbox, _atomic_write
from repro.serving.scheduler import Scheduler
from repro.serving.tracing import Tracer

DEFAULT_MODEL_SPEC: Dict[str, Any] = {
    "config": "qwen2-0.5b", "seed": 0,
    "engine": {"max_seq_len": 48, "max_slots": 3, "kv_block_size": 8,
               "prefill_chunk": 8, "prefill_batch": 2},
}


def build_engine(model_spec: Optional[Dict[str, Any]]) -> ServingEngine:
    """Deterministic engine from a declarative spec — both ends of a
    process boundary build bit-identical weights from it."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    spec = dict(DEFAULT_MODEL_SPEC, **(model_spec or {}))
    cfg = get_smoke_config(spec["config"])
    params = T.init_params(cfg, jax.random.PRNGKey(int(spec["seed"])))
    return ServingEngine(cfg, params, **dict(spec.get("engine", {})))


class ReplicaWorker:
    """The serve loop, factored so subprocess and mock execution share
    every line: ``iterate()`` is one pump (messages -> step -> results
    -> heartbeat); ``serve_forever()`` is the subprocess driver."""

    def __init__(self, spool, replica: str,
                 engine: Optional[ServingEngine] = None,
                 model_spec: Optional[Dict[str, Any]] = None,
                 tracing: bool = True):
        self.mailbox = Mailbox(spool, replica)
        self.replica = replica
        self.tracer = Tracer(enabled=tracing, name=replica)
        self.sched = Scheduler(engine or build_engine(model_spec),
                               tracer=self.tracer)
        # gateway rid <-> local rid (the worker's scheduler numbers its
        # own; results and heartbeats always speak gateway rids)
        self._local_of: Dict[int, int] = {}
        self._gateway_of: Dict[int, int] = {}
        self.draining = False
        self.stopped = False
        self.finished = False
        self._hb_seq = 0

    # -- message handling ----------------------------------------------------

    def _handle(self, msg: Dict[str, Any]) -> None:
        kind = msg["kind"]
        if kind == "submit":
            req = Request(np.asarray(msg["prompt"], np.int32),
                          SamplingParams(**msg.get("params", {})),
                          tenant=msg.get("tenant", "default"))
            local = self.sched.submit(
                req, resume_emitted=msg.get("resume_emitted") or None,
                retry=bool(msg.get("retry")), admit_while_draining=True)
            grid = int(msg["rid"])
            self._local_of[grid] = local
            self._gateway_of[local] = grid
        elif kind == "drain":
            self.draining = True
            self.sched.draining = True
        elif kind == "stop":
            self.stopped = True
        # unknown kinds are ignored: a newer gateway may speak additions
        # an older worker does not know — forward-compatible no-op

    def _publish_results(self) -> None:
        for local, grid in list(self._gateway_of.items()):
            if local in self.sched.done:
                toks = self.sched.output(local)
                self.mailbox.post_to_gateway(
                    "result", rid=grid,
                    tokens=[int(t) for t in np.asarray(toks)])
                del self._gateway_of[local]
                del self._local_of[grid]

    def _emitted_map(self) -> Dict[str, List[int]]:
        out: Dict[str, List[int]] = {}
        states = list(self.sched.queue)
        states += list(self.sched.active.values())
        states += list(self.sched.prefilling.values())
        for st in states:
            grid = self._gateway_of.get(st.rid)
            if grid is not None:
                out[str(grid)] = [int(t) for t in st.emitted]
        return out

    def _heartbeat(self) -> None:
        self._hb_seq += 1
        eng = self.sched.engine
        live = {self._gateway_of[st.rid]
                for st in self.sched.active.values()
                if st.rid in self._gateway_of}
        pre = {self._gateway_of[st.rid]
               for st in self.sched.prefilling.values()
               if st.rid in self._gateway_of}
        queued = {g for g in self._local_of if g not in live | pre}
        self.mailbox.write_heartbeat({
            "seq": self._hb_seq,
            "replica": self.replica,
            "decode_steps": int(eng.decode_steps),
            "prefill_tokens": int(eng.prefill_tokens_executed),
            "completed": int(self.sched.metrics.requests_completed),
            "preemptions": int(self.sched.preemptions),
            "queued": sorted(queued),
            "active": sorted(live),
            "prefilling": sorted(pre),
            "emitted": self._emitted_map(),
            "draining": self.draining,
        })

    # -- lifecycle -----------------------------------------------------------

    def iterate(self) -> bool:
        """One pump.  Returns True when anything observable happened
        (message consumed, scheduler work done, result published)."""
        msgs = self.mailbox.collect_inbox()
        for msg in msgs:
            self._handle(msg)
        stepped = False
        if not self.stopped and self.sched.has_work:
            self.sched.step()
            stepped = True
        before = len(self._gateway_of)
        self._publish_results()
        published = len(self._gateway_of) != before
        self._heartbeat()
        # only an explicit stop ends the worker: an idle draining
        # replica must stay up, because the gateway may still route a
        # salvaged request to it (failover retries admit while draining)
        if self.stopped:
            self._finalize("completed")
        return bool(msgs) or stepped or published

    def _write_status(self, state: str, error: str = "") -> None:
        _atomic_write(self.mailbox.home / "status.json",
                      json.dumps({"state": state, "error": error,
                                  "replica": self.replica},
                                 sort_keys=True))

    def _finalize(self, state: str, error: str = "") -> None:
        if self.finished:
            return
        self.finished = True
        self._write_status(state, error)
        self.mailbox.post_to_gateway("status", state=state, error=error)
        try:
            self.tracer.export_jsonl(self.mailbox.trace_path)
        except OSError:
            pass                       # trace export is best-effort

    def fail(self, error: BaseException) -> None:
        """Crash path: record the typed failure for the backend and the
        gateway, then mark the worker finished."""
        self._finalize("failed", error=repr(error))

    def serve_forever(self, poll_interval_s: float = 0.005) -> int:
        """Subprocess driver: pump until drained or stopped.  Returns
        the process exit code (0 clean, 1 crashed)."""
        try:
            while not self.finished:
                if not self.iterate():
                    time.sleep(poll_interval_s)
            return 0
        except BaseException as e:     # noqa: BLE001 — crash reporting
            self.fail(e)
            traceback.print_exc()
            return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fabric replica worker (mailbox transport)")
    ap.add_argument("--spool", required=True)
    ap.add_argument("--replica", required=True)
    ap.add_argument("--model-spec", default=None,
                    help="JSON model spec (config/seed/engine kwargs)")
    ap.add_argument("--image-dir", default=None,
                    help="unpacked capsule image; when given the serve "
                         "loop runs inside CapsuleRuntime.run (ch-run)")
    ap.add_argument("--poll-interval-s", type=float, default=0.005)
    args = ap.parse_args(argv)
    model_spec = json.loads(args.model_spec) if args.model_spec else None
    worker = ReplicaWorker(Path(args.spool), args.replica,
                           model_spec=model_spec)

    def loop() -> int:
        return worker.serve_forever(args.poll_interval_s)

    if args.image_dir:
        from repro.core.container import CapsuleRuntime
        res = CapsuleRuntime().run(
            Path(args.image_dir), loop,
            env={"REPRO_FABRIC_REPLICA": args.replica,
                 "REPRO_FABRIC_SPOOL": str(args.spool)})
        return int(res.value)
    return loop()


def spec_to_args(spool, replica: str,
                 model_spec: Optional[Dict[str, Any]] = None,
                 image_dir: Optional[str] = None) -> List[str]:
    """The worker argv (minus the interpreter) for a given spec — shared
    by LocalProcessBackend's Popen and SlurmBackend's script payload."""
    argv = ["-m", "repro.serving.fabric.worker",
            "--spool", str(spool), "--replica", replica]
    if model_spec:
        argv += ["--model-spec", json.dumps(model_spec, sort_keys=True)]
    if image_dir:
        argv += ["--image-dir", str(image_dir)]
    return argv


if __name__ == "__main__":
    sys.exit(main())
