"""Cross-process capsule replica fabric (see serving/README.md).

Pluggable :class:`SchedulerBackend` adapters launch replica workers
(Slurm script rendering, real subprocesses, or a deterministic mock),
a shared-filesystem mailbox carries submit/result/heartbeat messages,
and :class:`RemoteScheduler` makes each worker look like an in-process
replica to :class:`~repro.serving.gateway.ReplicaGateway` — so health,
failover, salvage-resume, and retry carry over unchanged.
"""
from repro.serving.fabric.backends import (COMPLETED, FAILED, PENDING,
                                           RUNNING, JobHandle,
                                           LocalProcessBackend,
                                           MockBackend, SchedulerBackend,
                                           SlurmBackend, WorkerSpec)
from repro.serving.fabric.mailbox import Mailbox, MailboxError
from repro.serving.fabric.registry import (CapacityError, ClusterRegistry,
                                           Partition)
from repro.serving.fabric.remote import (RemoteScheduler,
                                         collect_fabric_traces,
                                         launch_fabric_replicas,
                                         shutdown_fabric)
from repro.serving.fabric.worker import (DEFAULT_MODEL_SPEC, ReplicaWorker,
                                         build_engine)

__all__ = [
    "COMPLETED", "FAILED", "PENDING", "RUNNING",
    "CapacityError", "ClusterRegistry", "Partition",
    "DEFAULT_MODEL_SPEC", "JobHandle", "LocalProcessBackend",
    "Mailbox", "MailboxError", "MockBackend", "RemoteScheduler",
    "ReplicaWorker", "SchedulerBackend", "SlurmBackend", "WorkerSpec",
    "build_engine", "collect_fabric_traces", "launch_fabric_replicas",
    "shutdown_fabric",
]
