"""Gateway-side proxy: a remote replica that quacks like a Scheduler.

:class:`RemoteScheduler` implements the exact surface
:class:`~repro.serving.gateway.ReplicaGateway` drives — ``submit`` /
``step`` / ``abort`` / ``output`` / the progress-signature counters —
by exchanging mailbox messages with a worker launched through a
:class:`~repro.serving.fabric.backends.SchedulerBackend`.  The PR 9
failure machinery then carries over *unchanged*:

* the progress signature is fed from heartbeat counters, so a worker
  whose heartbeats stop looks exactly like a wedged in-process replica
  and climbs the HEALTHY -> DEGRADED -> QUARANTINED ladder;
* a worker whose process dies (backend poll FAILED, or a ``failed``
  status message) raises :class:`~repro.serving.faults.ReplicaCrashed`
  from ``step()`` — the gateway's fatal path, DEAD + salvage;
* heartbeats carry per-request emitted-so-far tokens, so salvage
  re-routes with ``resume_emitted`` and greedy outputs stay
  bit-identical to a fault-free run across the process boundary;
* a result arriving for a request the gateway already salvaged
  elsewhere (a slow worker racing its own failover) is dropped
  idempotently.

Quarantine auto-rejoin maps to :meth:`RemoteScheduler.respawn`: cancel
the old job, submit a fresh worker for the same spec through the same
backend — the cross-process analogue of relaunching the capsule.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving.engine import Request
from repro.serving.fabric.backends import (COMPLETED, FAILED, JobHandle,
                                           SchedulerBackend, WorkerSpec)
from repro.serving.fabric.mailbox import Mailbox
from repro.serving.faults import ReplicaCrashed
from repro.serving.gateway import CapsuleReplica, ReplicaGateway
from repro.serving.tracing import Tracer, export_jsonl


class _RemoteKVView:
    """Just enough KV surface for the gateway's rejoin bookkeeping."""
    block_size = 16
    prefix_pool = None


class _RemoteEngineView:
    """Progress counters mirrored from heartbeats; the gateway's
    ``_progress_sig`` reads these exactly like a local engine's."""

    def __init__(self):
        self.decode_steps = 0
        self.prefill_tokens_executed = 0
        self.kv = _RemoteKVView()
        self.fault_injector = None


@dataclass
class _RemoteAbortState:
    """What ``abort()`` hands the gateway's salvage loop — same fields
    it reads off a local ``_ReqState``."""
    rid: int
    emitted: List[int] = field(default_factory=list)


class RemoteScheduler:
    """Scheduler-shaped proxy over one worker job + its mailbox."""

    # surface the gateway reads but a remote replica cannot offer
    prefix_cache = None
    profiler = None
    max_admissions_per_step = None
    prefill_token_budget = None

    def __init__(self, backend: SchedulerBackend, spec: WorkerSpec, *,
                 tracer: Optional[Tracer] = None,
                 step_wait_s: float = 2.0,
                 boot_timeout_s: float = 180.0,
                 poll_interval_s: float = 0.01):
        self.backend = backend
        self.spec = spec
        self.mailbox = Mailbox(spec.spool, spec.replica)
        self.tracer = tracer or Tracer(name=spec.replica)
        self.engine = _RemoteEngineView()
        self.fault_injector = None
        self.preemptions = 0
        # a synchronous backend's worker only progresses inside poll(),
        # so waiting wall-clock time for it would deadlock
        self.step_wait_s = 0.0 if backend.synchronous else step_wait_s
        self.boot_timeout_s = 0.0 if backend.synchronous else boot_timeout_s
        self.poll_interval_s = poll_interval_s
        self._next_rid = 0
        self._requests: Dict[int, Request] = {}     # outstanding, by rid
        self.queue: Dict[int, Request] = {}
        self.active: Dict[int, Request] = {}
        self.prefilling: Dict[int, Request] = {}
        self.done: Dict[int, np.ndarray] = {}
        self._emitted: Dict[int, List[int]] = {}
        self._first_token_seen: set = set()
        self._hb_seq = -1
        self._worker_exited = False
        self._draining = False
        self.handle: JobHandle = backend.submit(spec)

    # -- scheduler surface ---------------------------------------------------

    @property
    def name(self) -> str:
        return self.spec.replica

    @property
    def load(self) -> int:
        return len(self.queue) + len(self.active) + len(self.prefilling)

    @property
    def has_work(self) -> bool:
        return bool(self._requests)

    @property
    def metrics(self):
        return self.tracer.metrics

    @property
    def draining(self) -> bool:
        return self._draining

    @draining.setter
    def draining(self, value: bool) -> None:
        value = bool(value)
        if value and not self._draining:
            self.mailbox.post_to_worker("drain")
        self._draining = value

    def prefix_match_len(self, prompt) -> int:
        # no cross-process prefix introspection: remote replicas route
        # by hash ownership / least load only
        return 0

    def submit(self, request: Request, *,
               resume_emitted: Optional[List[int]] = None,
               retry: bool = False,
               admit_while_draining: bool = False) -> int:
        if self._draining and not admit_while_draining:
            raise RuntimeError(f"{self.name} is draining")
        if request.encoder_input is not None:
            raise TypeError(
                "the fabric mailbox transport does not carry encoder "
                "inputs; route enc-dec requests to in-process replicas")
        rid = self._next_rid
        self._next_rid += 1
        p = request.params
        self.mailbox.post_to_worker(
            "submit", rid=rid,
            prompt=[int(t) for t in np.asarray(request.prompt)],
            params={"temperature": float(p.temperature),
                    "greedy": bool(p.greedy),
                    "max_new_tokens": int(p.max_new_tokens),
                    "eos_token": (int(p.eos_token)
                                  if p.eos_token is not None else None)},
            tenant=request.tenant,
            resume_emitted=[int(t) for t in (resume_emitted or [])],
            retry=retry)
        self._requests[rid] = request
        self.queue[rid] = request
        if resume_emitted:
            self._emitted[rid] = [int(t) for t in resume_emitted]
        self.tracer.submit(rid, request.tenant, retry=retry)
        return rid

    def step(self) -> None:
        """One gateway step: poll the backend, pump the mailbox, and —
        for asynchronous backends — wait up to ``step_wait_s`` for the
        worker to make observable progress, so the gateway's step
        cadence tracks worker cadence instead of spinning the health
        ladder on wall-clock noise.  Before the very first heartbeat
        the wait stretches to ``boot_timeout_s``: a subprocess worker
        pays interpreter + jit warmup before it can possibly speak, and
        that must not read as a health strike."""
        wait = (self.boot_timeout_s if self._hb_seq < 0
                else self.step_wait_s)
        deadline = time.monotonic() + wait
        while True:
            progressed = self._pump()
            if progressed or not self._requests:
                return
            if time.monotonic() >= deadline:
                return
            time.sleep(self.poll_interval_s)

    def _pump(self) -> bool:
        state = self.backend.poll(self.handle)
        progressed = self._pump_mailbox()
        if state == FAILED:
            raise ReplicaCrashed(
                f"{self.name}: worker job {self.handle.job_id} failed "
                f"({self.handle.error or 'no error recorded'})")
        if state == COMPLETED and self._requests:
            raise ReplicaCrashed(
                f"{self.name}: worker exited with "
                f"{len(self._requests)} request(s) outstanding")
        return progressed

    def _pump_mailbox(self) -> bool:
        progressed = False
        hb = self.mailbox.read_heartbeat()
        if hb is not None and int(hb.get("seq", 0)) != self._hb_seq:
            # a fresh heartbeat ends the step's wait loop (the worker is
            # alive and spoke); whether it counts as *health* progress
            # is the gateway's call via the progress signature
            progressed = True
            self._hb_seq = int(hb.get("seq", 0))
            eng = self.engine
            eng.decode_steps = int(hb.get("decode_steps", 0))
            eng.prefill_tokens_executed = int(hb.get("prefill_tokens", 0))
            self.preemptions = int(hb.get("preemptions", 0))
            stages = {rid: "queued" for rid in self._requests}
            for stage in ("active", "prefilling"):
                for rid in hb.get(stage, []):
                    if int(rid) in stages:
                        stages[int(rid)] = stage
            self.queue.clear()
            self.active.clear()
            self.prefilling.clear()
            buckets = {"queued": self.queue, "active": self.active,
                       "prefilling": self.prefilling}
            for rid, stage in stages.items():
                buckets[stage][rid] = self._requests[rid]
            for rid_s, toks in (hb.get("emitted") or {}).items():
                rid = int(rid_s)
                if rid in self._requests:
                    self._emitted[rid] = [int(t) for t in toks]
                    if toks and rid not in self._first_token_seen:
                        self._first_token_seen.add(rid)
                        self.tracer.first_token(rid)
        for msg in self.mailbox.collect_outbox():
            if msg["kind"] == "result":
                rid = int(msg["rid"])
                if rid not in self._requests:
                    continue       # duplicate / already-salvaged: no-op
                tokens = np.asarray(msg.get("tokens", []), np.int32)
                self.done[rid] = tokens
                self._forget(rid)
                if rid not in self._first_token_seen:
                    self._first_token_seen.add(rid)
                    self.tracer.first_token(rid)
                self.tracer.retire(rid, len(tokens), "complete")
                progressed = True
            elif msg["kind"] == "status":
                self._worker_exited = True
                if msg.get("state") == "failed":
                    raise ReplicaCrashed(
                        f"{self.name}: worker reported failure: "
                        f"{msg.get('error', '')}")
        return progressed

    def _forget(self, rid: int) -> None:
        self._requests.pop(rid, None)
        self.queue.pop(rid, None)
        self.active.pop(rid, None)
        self.prefilling.pop(rid, None)
        self._emitted.pop(rid, None)

    def output(self, rid: int) -> np.ndarray:
        return self.done[rid]

    def abort(self) -> List[_RemoteAbortState]:
        """Salvage: hand back every outstanding request with its
        last-heartbeat emitted tokens, then forget them — late results
        from a still-twitching worker are dropped idempotently."""
        states = [_RemoteAbortState(rid, list(self._emitted.get(rid, [])))
                  for rid in sorted(self._requests)]
        for st in states:
            self._forget(st.rid)
        return states

    # -- lifecycle -----------------------------------------------------------

    def respawn(self, draining: bool = False) -> "RemoteScheduler":
        """Quarantine-exit relaunch: cancel the old job, clear the dead
        worker's spool leavings, submit a fresh worker for the same
        spec.  Returns self — the gateway swaps it in as the replica's
        scheduler, rid numbering and finished outputs carried over."""
        self.backend.cancel(self.handle)
        for box in (self.mailbox.inbox, self.mailbox.outbox):
            for path in box.glob("*.json"):
                path.unlink()
        for leftover in (self.mailbox.heartbeat_path,
                         self.mailbox.home / "status.json"):
            if leftover.exists():
                leftover.unlink()
        self._hb_seq = -1
        self._worker_exited = False
        self.handle = self.backend.submit(self.spec)
        self._draining = False
        if draining:
            self.draining = True
        return self

    def shutdown(self, timeout_s: float = 30.0) -> None:
        """Stop the worker: post stop, give it a moment to exit clean
        (status + trace export), then cancel through the backend."""
        self.mailbox.post_to_worker("stop")
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            state = self.backend.poll(self.handle)
            if state in (COMPLETED, FAILED):
                return
            if not self.backend.synchronous:
                time.sleep(self.poll_interval_s)
        self.backend.cancel(self.handle)


# ---------------------------------------------------------------------------
# fleet launch / teardown
# ---------------------------------------------------------------------------

def launch_fabric_replicas(
        n: int, backend: SchedulerBackend, spool, *,
        model_spec: Optional[Dict[str, Any]] = None,
        image_dir: Optional[str] = None, partition: str = "general",
        tracing: bool = False, step_wait_s: float = 2.0,
        **gateway_kw) -> ReplicaGateway:
    """Launch ``n`` replica workers through ``backend`` and front them
    with a :class:`ReplicaGateway` — the cross-process analogue of
    :func:`~repro.serving.gateway.launch_capsule_replicas`.  Capacity
    is validated per worker before submit (CapacityError aborts the
    whole launch), and each replica records its backend/job bookkeeping
    where the in-process launcher records ch-run's."""
    if n <= 0:
        raise ValueError(f"need at least one replica, got n={n}")
    spool = Path(spool)
    replicas = []
    for r in range(n):
        name = f"replica{r}"
        spec = WorkerSpec(replica=name, spool=spool,
                          model_spec=model_spec, image_dir=image_dir,
                          partition=partition)
        rs = RemoteScheduler(
            backend, spec, tracer=Tracer(enabled=tracing, name=name),
            step_wait_s=step_wait_s)
        replicas.append(CapsuleReplica(
            name, rs,
            capsule={"backend": type(backend).__name__,
                     "job_id": rs.handle.job_id, "partition": partition,
                     "spool": str(spool)}))
    return ReplicaGateway(replicas, **gateway_kw)


def shutdown_fabric(gateway: ReplicaGateway,
                    timeout_s: float = 30.0) -> None:
    """Stop every remote replica's worker (in-process replicas are
    untouched)."""
    for rep in gateway.replicas:
        if isinstance(rep.scheduler, RemoteScheduler):
            rep.scheduler.shutdown(timeout_s)


def collect_fabric_traces(gateway: ReplicaGateway, spool,
                          out_path) -> int:
    """Merge the fleet's gateway-side events with every worker-side
    trace file the workers exported into one replica-stamped JSONL, and
    return the merged event count.  Worker clocks are per-process
    monotonic — events still sort by ``ts``, but cross-process ordering
    is only meaningful per replica, which is how the fleet report reads
    them."""
    import json as _json
    events: List[Dict[str, Any]] = list(gateway.trace_events())
    for home in sorted(Path(spool).iterdir()):
        trace = home / "trace.jsonl"
        if not trace.is_file():
            continue
        with trace.open() as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(_json.loads(line))
    events.sort(key=lambda ev: (ev.get("replica", ""), ev["ts"]))
    export_jsonl(events, out_path)
    return len(events)
