"""Shared-filesystem mailbox transport for the cross-process fabric.

The paper's clusters are air-gapped — compute nodes have no external
network and sites routinely firewall node-to-node sockets — but every
allocation sees the same parallel filesystem.  The fabric therefore
speaks *files*: each replica owns a spool directory with an inbox
(gateway -> worker), an outbox (worker -> gateway), and a heartbeat
file.  Every write is atomic (same-directory ``.tmp`` + ``os.replace``,
the same idiom as :func:`repro.serving.metrics.atomic_write_json`), so a
reader can never observe a half-written message: a ``*.tmp`` file is
in-flight and skipped; a ``*.json`` file is complete by construction.
A ``*.json`` file that nonetheless fails to parse means the spool
itself was corrupted (disk fault, manual tampering) and surfaces as a
typed :class:`MailboxError`, never a raw ``JSONDecodeError``.

Message files are named ``{seq:08d}.{nonce}.json`` — lexicographic
order is FIFO per sender, and the nonce (sender pid) keeps two writers
from colliding.  Consuming a message unlinks it, so re-delivery cannot
happen through the transport; duplicate *results* (a slow worker
finishing a request the gateway already salvaged elsewhere) are handled
idempotently one layer up, in the gateway-side proxy.

Spool layout (one fleet)::

    spool/
      <replica>/
        inbox/          submit / drain / stop   (gateway -> worker)
        outbox/         result / status         (worker -> gateway)
        heartbeat.json  monotonic seq + progress counters + emitted map
        trace.jsonl     worker tracer export, written at exit
      jobs/             rendered sbatch scripts (SlurmBackend)
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional


class MailboxError(RuntimeError):
    """Typed transport failure: a completed message file that cannot be
    parsed (spool corruption).  Callers treat it like any other replica
    failure — the health ladder, not a traceback, decides what happens."""


def _atomic_write(path: Path, text: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


class Mailbox:
    """One replica's spool endpoints.  Both ends construct one over the
    same ``(root, replica)``; the gateway posts to the inbox and
    collects the outbox, the worker does the reverse."""

    def __init__(self, root, replica: str):
        self.root = Path(root)
        self.replica = replica
        self.home = self.root / replica
        self.inbox = self.home / "inbox"
        self.outbox = self.home / "outbox"
        self.inbox.mkdir(parents=True, exist_ok=True)
        self.outbox.mkdir(parents=True, exist_ok=True)
        self._seq = 0

    # -- messages ------------------------------------------------------------

    def _post(self, box: Path, kind: str, payload: Dict[str, Any]) -> Path:
        self._seq += 1
        name = f"{self._seq:08d}.{os.getpid()}.json"
        path = box / name
        _atomic_write(path, json.dumps({"kind": kind, **payload},
                                       sort_keys=True))
        return path

    def post_to_worker(self, kind: str, **payload) -> Path:
        return self._post(self.inbox, kind, payload)

    def post_to_gateway(self, kind: str, **payload) -> Path:
        return self._post(self.outbox, kind, payload)

    @staticmethod
    def _collect(box: Path) -> List[Dict[str, Any]]:
        paths = sorted(box.glob("*.json"))
        out: List[Dict[str, Any]] = []
        for path in paths:
            try:
                msg = json.loads(path.read_text())
            except (OSError, ValueError) as e:
                raise MailboxError(
                    f"corrupt mailbox message {path}: {e}") from e
            if not isinstance(msg, dict) or "kind" not in msg:
                raise MailboxError(
                    f"malformed mailbox message {path}: no 'kind'")
            out.append(msg)
        # parse-then-consume: nothing is unlinked until every pending
        # message parsed, so a corrupt file surfaces as a typed error
        # without silently eating the valid messages sorted before it
        for path in paths:
            path.unlink()
        return out

    def collect_inbox(self) -> List[Dict[str, Any]]:
        """Worker side: consume pending gateway messages, FIFO."""
        return self._collect(self.inbox)

    def collect_outbox(self) -> List[Dict[str, Any]]:
        """Gateway side: consume pending worker messages, FIFO."""
        return self._collect(self.outbox)

    # -- heartbeat -----------------------------------------------------------

    @property
    def heartbeat_path(self) -> Path:
        return self.home / "heartbeat.json"

    def write_heartbeat(self, payload: Dict[str, Any]) -> None:
        _atomic_write(self.heartbeat_path,
                      json.dumps(payload, sort_keys=True))

    def read_heartbeat(self) -> Optional[Dict[str, Any]]:
        """The worker's latest heartbeat, or None before the first one.
        A heartbeat that fails to parse is spool corruption — typed, like
        a corrupt message (the file is atomically replaced, so a normal
        race cannot produce this)."""
        try:
            text = self.heartbeat_path.read_text()
        except OSError:
            return None
        try:
            hb = json.loads(text)
        except ValueError as e:
            raise MailboxError(
                f"corrupt heartbeat {self.heartbeat_path}: {e}") from e
        if not isinstance(hb, dict):
            raise MailboxError(
                f"corrupt heartbeat {self.heartbeat_path}: not an object")
        return hb

    @property
    def trace_path(self) -> Path:
        return self.home / "trace.jsonl"
