"""Continuous-batching scheduler: admission queue + slot manager.

One scheduler drives one :class:`~repro.serving.engine.ServingEngine`
(conceptually: the serving process inside one ``ch-run`` capsule).  The
loop is the standard continuous-batching shape:

    admit:   drain the queue into free slots in *batches*: as many
             queued prompts as slots, KV blocks, and the engine's
             ``prefill_batch`` allow claim slots and become *in-flight
             prefills* (``engine.begin_prefill``); each request's
             prefix-cache probe still runs first so only uncached
             suffixes will execute;
    prefill: run at most ``prefill_token_budget`` executed token
             positions of chunked prefill across the in-flight cursors
             (``engine.advance_prefill``) — SplitFuse-style
             interleaving: instead of draining every admission's chunk
             rounds before the next decode step, each scheduler step is
             a *token-budgeted round* of prefill fused with one decode
             step, so running sequences never stall for a whole
             admission wave.  Rows whose prompt completes sample their
             first tokens in one vectorized call and join decode the
             same step; unfinished rows stay parked on the engine,
             resumable mid-prompt next step.  ``None`` (the default)
             removes the cap — wave-at-once admission, the PR 4 shape;
    decode:  one ``decode_once`` over the pooled cache advances *every*
             live sequence by one token, each sampled with its own
             ``SamplingParams`` (mid-prefill slots' rows are masked to
             the trash block by the engine);
    retire:  a sequence that hits its own ``max_new_tokens`` or emits
             its ``eos_token`` leaves immediately — its KV blocks
             return to the ring, its prefix-block pins are released,
             and the slot is refilled on the next admit, mid-decode of
             the others.

Partially-prefilled slots are first-class scheduler state
(``self.prefilling``): decode-time preemption may pick one as victim
(``engine.cancel_prefill`` — it wastes the least finished work), an
engine error during a prefill round re-queues every in-flight admission
with prefix pins released, gateway drain keeps stepping until in-flight
prefills finish, and a preempted mid-prefill request resumes later from
whatever the prefix cache holds at that point.

Prefix-cache interplay: the matched blocks are pinned (refcounted) for
the request's lifetime so LRU eviction can never reclaim KV a live
sequence was served from, and every admitted prompt is inserted back
into the radix tree right after its prefill, making its KV available to
the next request that shares it.  Co-admission respects this: a queued
request sharing at least one full KV block of prefix with a request
already collected into the current batch is deferred one round, so it
admits *after* the insert and HITs the shared prefix instead of
recomputing it in parallel — shared-prefix bursts serialize (each later
request then skips the shared compute), unrelated prompts batch.

With a paged engine the KV pool can be sized below worst case, so
``OutOfBlocks`` is a real event on both sides of the loop and neither
may lose a request:

    admission — the head request stays in the queue until its prefill
        blocks actually allocate; on ``OutOfBlocks`` its prefix pins are
        released, it returns to the *head*, and admission stops for the
        round (a retirement must free blocks first);
    decode    — when a live sequence cannot grow by one block, the most
        recently admitted *other* sequence is preempted (slot and blocks
        freed, prefix pins released, request re-queued at the head); a
        preempted request resumes later by re-prefilling its prompt plus
        the tokens it already emitted — recompute-style preemption, so
        no KV swap space is needed and greedy outputs are unchanged.

This replaces the seed engine's run-everything-to-the-global-max loop:
short requests stop costing decode work the step they finish, and
``decode_steps`` accounting makes the saving testable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.kvcache import OutOfBlocks
from repro.serving.metrics import ServingMetrics
from repro.serving.tracing import Tracer


@dataclass
class _ReqState:
    rid: int
    request: Request
    slot: int = -1
    pos: int = 0                       # next cache write position
    admit_seq: int = -1                # admission-recency (victim pick)
    emitted: List[int] = field(default_factory=list)
    finish_reason: str = ""
    cached_len: int = 0                # tokens served from the prefix cache
    prefix_blocks: List[int] = field(default_factory=list)   # pinned blocks
    inflight_seq: Optional[np.ndarray] = None   # sequence mid-prefill
    prefix_counted: bool = False       # one record_prefix per request
    admitted_before: bool = False      # re-admission => resumed span


class Scheduler:
    """Admission queue + continuous-batching slot manager for one engine."""

    def __init__(self, engine: ServingEngine,
                 metrics: Optional[ServingMetrics] = None,
                 clock=time.perf_counter,
                 max_admissions_per_step: Optional[int] = None,
                 prefill_token_budget: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 profile: bool = False,
                 fault_injector=None):
        self.engine = engine
        self.max_slots = engine.max_slots
        # deterministic fault injection (tests/chaos harness): the
        # injector fires at the top of step() and inside the engine's
        # prefill/decode ops; None (the default) costs one attribute
        # check per step
        self.fault_injector = fault_injector
        engine.fault_injector = fault_injector
        # cap on requests admitted per scheduler step (None = drain all
        # that fit).  1 reproduces the old one-at-a-time admission — the
        # benchmark baseline — and smooths decode latency under bursts.
        self.max_admissions_per_step = max_admissions_per_step
        # SplitFuse knob: max *executed* prefill token positions per
        # step (None = unbudgeted wave-at-once).  Each step then fuses
        # at most this much chunked prefill with one decode round, so
        # decode latency jitter under admission bursts is bounded by
        # the budget, not by the whole wave.
        if prefill_token_budget is not None and prefill_token_budget <= 0:
            raise ValueError(
                f"prefill_token_budget must be positive or None, got "
                f"{prefill_token_budget}")
        self.prefill_token_budget = prefill_token_budget
        # one recording path: the tracer owns the metrics and feeds its
        # counters; a disabled tracer (the default) only forwards —
        # near-zero overhead over calling the metrics directly.  The
        # tracer is also bound onto the engine / KV ledger / prefix
        # cache so their events land in the same per-replica buffer.
        if tracer is None:
            tracer = Tracer(metrics or ServingMetrics(clock=clock),
                            clock=clock)
        self.tracer = tracer
        self.metrics = tracer.metrics
        engine.tracer = tracer
        engine.kv.tracer = tracer
        if engine.prefix_cache is not None:
            engine.prefix_cache.tracer = tracer
        # step-phase profiling: with profile=True each phase is
        # bracketed by block_until_ready so the t0..t4 deltas measure
        # device time, not dispatch time (JAX is async); the windows
        # live on self.profiler.  Off by default — the sync points
        # serialize the pipeline and cost real throughput.
        self.profiler = None
        if profile:
            from repro.serving.profiling import StepProfiler
            self.profiler = StepProfiler()
        self.queue: deque = deque()
        self.active: Dict[int, _ReqState] = {}          # slot -> state
        self.prefilling: Dict[int, _ReqState] = {}      # slot -> mid-prefill
        self.done: Dict[int, _ReqState] = {}            # rid  -> state
        self.draining = False
        self.preemptions = 0               # decode-time OutOfBlocks defers
        self.admission_stalls = 0          # admit-time OutOfBlocks retries
        self._next_rid = 0
        self._admit_counter = 0            # monotonic admission stamp
        # eviction counting is per-scheduler; the cache outlives us
        pc = engine.prefix_cache
        self._evict_base = pc.stats.evicted_blocks if pc else 0

    @property
    def decode_steps(self) -> int:
        return self.metrics.decode_steps

    @property
    def prefix_cache(self):
        return self.engine.prefix_cache

    # -- submission ----------------------------------------------------------

    def submit(self, request: Request, *,
               resume_emitted: Optional[List[int]] = None,
               retry: bool = False,
               admit_while_draining: bool = False) -> int:
        """Queue a request.  The keyword knobs exist for gateway
        failover: ``resume_emitted`` seeds the request with tokens it
        already emitted on a failed replica (it re-prefills prompt +
        emitted[:-1] exactly like a recompute-preemption resume),
        ``retry=True`` records a retry instead of a second logical
        submit, and ``admit_while_draining`` lets a draining gateway
        re-home salvaged work past this scheduler's closed admission."""
        if self.draining and not admit_while_draining:
            raise RuntimeError("scheduler is draining; admission closed")
        if len(request.prompt) == 0:
            raise ValueError(
                "empty prompt: a request needs at least one token "
                "(the first sample comes from the prefill logits)")
        sp = request.params
        need = len(request.prompt) + sp.max_new_tokens
        if need > self.engine.max_seq_len:
            raise ValueError(
                f"prompt ({len(request.prompt)}) + max_new_tokens "
                f"({sp.max_new_tokens}) exceeds max_seq_len "
                f"({self.engine.max_seq_len})")
        kv = self.engine.kv
        if kv._blocks_for(need) > kv.pool.num_blocks:
            raise ValueError(
                f"request needs {kv._blocks_for(need)} KV blocks at full "
                f"length but the pool holds {kv.pool.num_blocks}; it could "
                "never be scheduled even alone")
        rid = self._next_rid
        self._next_rid += 1
        st = _ReqState(rid, request)
        if resume_emitted:
            # salvage resume: the emitted tokens ride the recompute-
            # preemption path — _collect_batch re-prefills prompt +
            # emitted[:-1] and the span reads resumed=True
            st.emitted = [int(t) for t in resume_emitted]
            st.admitted_before = True
        self.queue.append(st)
        self.tracer.submit(rid, request.tenant, retry=retry)
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active or self.prefilling)

    @property
    def load(self) -> int:
        return len(self.queue) + len(self.active) + len(self.prefilling)

    def prefix_match_len(self, prompt: np.ndarray) -> int:
        """Longest cached prefix this replica holds (gateway affinity)."""
        pc = self.prefix_cache
        return pc.peek(prompt) if pc is not None else 0

    # -- the loop ------------------------------------------------------------

    def _shares_block(self, a: np.ndarray, b: np.ndarray) -> bool:
        """True when two prompts share at least one full KV block of
        common prefix — i.e. co-admitting them would recompute KV the
        prefix cache could have shared."""
        n = min(len(a), len(b), self.engine.kv.block_size)
        return (n == self.engine.kv.block_size
                and bool(np.array_equal(a[:n], b[:n])))

    def _collect_batch(self, limit: int):
        """Pop as many admissible head-of-queue requests as slots, KV
        blocks, and ``limit`` allow.  Prefix pins are taken here; the
        caller must release them if the prefill never happens.  Returns
        ``(states, seqs, starts, blocks_lists)`` in queue order."""
        kv = self.engine.kv
        pc = self.prefix_cache
        states, seqs, starts, blocks_lists = [], [], [], []
        # in-flight prefills haven't inserted their prefix yet either:
        # a candidate sharing a block with one must defer the same way
        inflight_seqs = [st.inflight_seq for st in self.prefilling.values()
                         if st.inflight_seq is not None]
        blocks_needed = 0
        while (self.queue and len(states) < limit
               and len(states) < kv.free_slot_count):
            st = self.queue[0]
            req = st.request
            if req.params.max_new_tokens <= 0:      # nothing to generate
                self.queue.popleft()
                st.finish_reason = "length"
                self.done[st.rid] = st
                self.tracer.retire(st.rid, 0, "length")
                continue
            resumed = bool(st.emitted)              # preempted earlier
            # a resumed request re-prefills prompt + all emitted tokens
            # except the last, which is still waiting to be fed to decode
            seq = (req.prompt if not resumed else
                   np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(st.emitted[:-1], np.int32)]))
            if kv.pool.available < blocks_needed + kv._blocks_for(len(seq)):
                # KV pool dry for THIS request: stop collecting BEFORE
                # touching the prefix cache so a request parked at the
                # head doesn't re-count lookup stats (or churn pins)
                # once per retry; stall only if nothing at all fit
                if not states:
                    self.admission_stalls += 1
                    self.tracer.admission_stall(
                        "kv_pool_dry", len(self.queue), rid=st.rid)
                break
            if pc is not None and any(
                    self._shares_block(seq, s)
                    for s in seqs + inflight_seqs):
                # the candidate shares >= one KV block of prefix with a
                # request already in this batch: defer it one round so
                # it can HIT the prefix the earlier request is about to
                # insert instead of recomputing it in parallel —
                # shared-prefix bursts serialize, unrelated prompts batch
                break
            cached_len, blocks = (0, [])
            if pc is not None:
                cached_len, blocks = pc.lookup(seq)
            self.queue.popleft()
            st.cached_len, st.prefix_blocks = cached_len, blocks
            states.append(st)
            seqs.append(seq)
            starts.append(cached_len)
            blocks_lists.append(blocks)
            blocks_needed += kv._blocks_for(len(seq))
        return states, seqs, starts, blocks_lists

    def _admit(self) -> int:
        """Batched admission: claim slots + pins and register in-flight
        prefill cursors (no chunk rounds yet — those run under the
        budget in ``_advance_prefill``).  Returns how many requests were
        admitted (the step loop uses this to tell a capped-but-
        progressing round from a genuine admission deadlock)."""
        admitted = 0
        pc = self.prefix_cache
        while self.queue and self.engine.kv.free_slot_count > 0:
            limit = self.engine.prefill_batch
            if self.max_admissions_per_step is not None:
                limit = min(limit, self.max_admissions_per_step - admitted)
            if limit <= 0:
                return admitted
            states, seqs, starts, blocks_lists = self._collect_batch(limit)
            if not states:
                return admitted
            try:
                cursors = self.engine.begin_prefill(
                    seqs, [st.request.encoder_input for st in states],
                    start_pos=starts, prefix_blocks=blocks_lists)
            except Exception as e:
                # never lose a request or its pins: the engine released
                # every slot (all-or-nothing), so requeue the whole
                # batch at the head, in order.  OutOfBlocks (unreachable
                # given the pre-check) stalls; anything else — device
                # OOM, an engine assert — propagates with the scheduler
                # state intact, so the caller can retry or drain.
                for st, blocks in zip(reversed(states),
                                      reversed(blocks_lists)):
                    if pc is not None and blocks:
                        pc.release(blocks)
                    st.prefix_blocks = []
                    self.queue.appendleft(st)
                if not isinstance(e, OutOfBlocks):
                    raise
                self.admission_stalls += 1
                self.tracer.admission_stall(
                    "out_of_blocks", len(self.queue),
                    rid=states[0].rid if states else -1)
                return admitted
            admitted += len(states)
            for st, seq, cur in zip(states, seqs, cursors):
                st.slot = cur.slot
                st.admit_seq = self._admit_counter
                self._admit_counter += 1
                st.inflight_seq = seq
                st.pos = len(seq)          # cache position once prefill ends
                self.tracer.bind_slot(cur.slot, st.rid)
                if pc is not None:
                    # the probe event fires every admission (a resumed
                    # request's re-probe is part of its span), but the
                    # metrics count one prefix outcome per request, even
                    # across mid-prefill preemptions and re-admissions
                    self.tracer.prefix_probe(st.rid, st.cached_len,
                                             len(seq),
                                             count=not st.prefix_counted)
                    st.prefix_counted = True
                self.tracer.admit(st.rid, cur.slot, len(seq), st.cached_len,
                                  resumed=st.admitted_before)
                st.admitted_before = True
                self.prefilling[cur.slot] = st
        return admitted

    def _advance_prefill(self) -> int:
        """One budgeted round of chunked prefill across every in-flight
        admission.  Completed rows insert their prefix, sample their
        first token (fresh admissions) in one vectorized call, and join
        the decode set; unfinished rows stay in ``self.prefilling`` with
        their cursor parked on the engine.  Returns how many rows
        completed."""
        if not self.prefilling:
            return 0
        pc = self.prefix_cache
        real0 = self.engine.prefill_tokens
        exec0 = self.engine.prefill_tokens_executed
        try:
            completed = self.engine.advance_prefill(
                token_budget=self.prefill_token_budget)
        except Exception:
            # the engine released every in-flight slot (all-or-nothing
            # per advance call): requeue every mid-prefill request with
            # its pins released, oldest admission back at the head, then
            # let the error propagate with the scheduler state intact
            for st in sorted(self.prefilling.values(),
                             key=lambda s: -s.admit_seq):
                if pc is not None and st.prefix_blocks:
                    pc.release(st.prefix_blocks)
                self.tracer.unbind_slot(st.slot)
                st.prefix_blocks = []
                st.slot = -1
                st.cached_len = 0
                st.inflight_seq = None
                self.queue.appendleft(st)
            self.prefilling.clear()
            raise
        executed = self.engine.prefill_tokens_executed - exec0
        self.tracer.prefill_work(
            self.engine.prefill_tokens - real0, executed)
        if self.prefill_token_budget is not None:
            self.tracer.budget_round(executed, self.prefill_token_budget)
        fresh: List[_ReqState] = []
        fresh_logits: List[jnp.ndarray] = []
        for cur in completed:
            st = self.prefilling.pop(cur.slot)
            seq, st.inflight_seq = st.inflight_seq, None
            if pc is not None:
                pc.insert(seq, st.slot)
                self.metrics.prefix_evictions = (pc.stats.evicted_blocks
                                                 - self._evict_base)
            if st.emitted:                      # resumed: last token pending
                self.active[st.slot] = st
            else:
                fresh.append(st)
                # stays device-resident: the only host sync of the round
                # is sample_tokens reading back the sampled token ids
                fresh_logits.append(cur.last_logits)
        if fresh:
            # every first token of the round in one vectorized sample
            toks = self.engine.sample_tokens(
                jnp.stack(fresh_logits),
                np.asarray([st.request.params.temperature
                            for st in fresh], np.float32),
                np.asarray([st.request.params.greedy for st in fresh]))
            for st, tok in zip(fresh, toks):
                tok = int(tok)
                st.emitted.append(tok)
                self.tracer.first_token(st.rid)
                if not self._maybe_retire(st, tok):
                    self.active[st.slot] = st
        return len(completed)

    def _preempt(self, st: _ReqState) -> None:
        """Defer a live or mid-prefill request: free its slot and KV
        blocks (cancelling the in-flight cursor if its prefill never
        finished), release its prefix pins, and put it back at the head
        of the queue.  It will resume by re-prefilling prompt + emitted
        tokens (recompute-style preemption) once blocks are available
        again, probing the prefix cache afresh — partial prefill work
        survives only through whatever prefixes are cached."""
        mid_prefill = st.slot in self.prefilling
        if mid_prefill:
            self.prefilling.pop(st.slot)
            self.engine.cancel_prefill(st.slot)
            st.inflight_seq = None
        else:
            self.active.pop(st.slot, None)
            self.engine.free_slot(st.slot)
        if st.prefix_blocks:
            self.prefix_cache.release(st.prefix_blocks)
            st.prefix_blocks = []
        self.tracer.preempt(st.rid, mid_prefill)
        self.tracer.unbind_slot(st.slot)
        st.slot = -1
        st.cached_len = 0
        self.queue.appendleft(st)
        self.preemptions += 1

    def _pick_victim(self, exclude_slot: int) -> Optional[_ReqState]:
        """Most recently *admitted* live or mid-prefill request other
        than the one trying to grow — freeing the youngest admission
        wastes the least finished work, and a mid-prefill slot (always
        among the youngest) wastes none of its decode progress.
        (Admission recency, not rid: a resumed old request is younger
        than a long-running new one.)"""
        candidates = [st for slot, st in self.active.items()
                      if slot != exclude_slot]
        candidates += [st for slot, st in self.prefilling.items()
                       if slot != exclude_slot]
        return (max(candidates, key=lambda st: st.admit_seq)
                if candidates else None)

    def _maybe_retire(self, st: _ReqState, tok: int) -> bool:
        sp = st.request.params
        reason = ""
        if len(st.emitted) >= sp.max_new_tokens:
            reason = "length"
        elif sp.eos_token is not None and tok == sp.eos_token:
            reason = "eos"
        if not reason:
            return False
        st.finish_reason = reason
        self.active.pop(st.slot, None)
        self.engine.free_slot(st.slot)
        if st.prefix_blocks:
            self.prefix_cache.release(st.prefix_blocks)
            st.prefix_blocks = []
        self.done[st.rid] = st
        self.tracer.retire(st.rid, len(st.emitted), reason)
        self.tracer.unbind_slot(st.slot)
        return True

    def _grow_or_preempt(self) -> None:
        """Back every live sequence's next token position with a block.
        When the pool is dry, preempt the youngest other request and
        retry; a sequence with nobody left to evict defers itself (it
        can always fit alone later — submit() guarantees that)."""
        for slot in sorted(self.active):
            st = self.active.get(slot)
            if st is None:                 # preempted earlier this pass
                continue
            while True:
                try:
                    self.engine.kv.ensure_capacity(slot, st.pos + 1)
                    break
                except OutOfBlocks:
                    victim = self._pick_victim(exclude_slot=slot)
                    self._preempt(victim if victim is not None else st)
                    if victim is None:
                        break              # st itself deferred; move on

    def _close_step(self, tr, decoded: bool, admitted: int, completed: int,
                    executed: int, t0: float, t1: float, t2: float,
                    t3: float) -> None:
        """Emit the per-step engine-timeline event (phase breakdown +
        gauges snapshot) and sample the step gauges into the metrics
        when a decode round actually ran (the pre-tracing semantics)."""
        kv = self.engine.kv
        t4 = tr.clock()
        tr.engine_step(
            decoded=decoded, queue_depth=len(self.queue),
            active=len(self.active), max_slots=self.max_slots,
            admitted=admitted, completed=completed,
            prefill_executed=executed, budget=self.prefill_token_budget,
            dur_admit_s=t1 - t0, dur_prefill_s=t2 - t1,
            dur_decode_s=t3 - t2, dur_sample_s=t4 - t3,
            free_blocks=kv.pool.available, free_slots=kv.free_slot_count,
            inflight=len(self.prefilling),
            prefix_pins=(kv.prefix_pool.in_use
                         if kv.prefix_pool is not None else 0))
        if self.profiler is not None:
            self.profiler.record_step(t1 - t0, t2 - t1, t3 - t2, t4 - t3)
        tr.check_slo()

    def step(self) -> bool:
        """One token-budgeted round: admit into free slots, run at most
        ``prefill_token_budget`` executed tokens of chunked prefill
        across in-flight admissions, then decode one token for every
        live sequence.  Returns False when there was nothing to do.

        Every call emits one ``engine_step`` trace event with the phase
        durations (admission / prefill-advance / decode dispatch /
        sample+retire) and a gauges snapshot, so a stalled request can
        be read against what the engine was actually doing that step."""
        fi = self.fault_injector
        if fi is not None and fi.on_step() == "stall":
            # injected wedge: claim liveness, do nothing.  This is the
            # capsule that hangs without exiting — return-value-based
            # progress checks are satisfied, only the gateway's
            # progress-signature watchdog can tell
            return True
        tr = self.tracer
        prof = self.profiler
        t0 = tr.clock()
        admitted = self._admit()
        if prof is not None:                 # device-accurate phase edges
            # deliberate: only when step profiling is armed, so phase
            # walls measure device time, not dispatch time
            jax.block_until_ready(self.engine.kv.cache)  # repro-lint: disable=RL001
        t1 = tr.clock()
        exec0 = self.engine.prefill_tokens_executed
        completed = self._advance_prefill()
        if prof is not None:
            # deliberate: profiler-gated phase edge (see above)
            jax.block_until_ready(self.engine.kv.cache)  # repro-lint: disable=RL001
        executed = self.engine.prefill_tokens_executed - exec0
        t2 = tr.clock()
        if not self.active:
            if self.prefilling:
                ret = True                 # prefill progressing; no decode yet
            elif self.queue and not admitted and not completed:
                # nothing live, nothing in flight, nothing admitted:
                # with the pool idle this is unservable demand, not a
                # transient — fail loudly instead of spinning forever
                raise RuntimeError(
                    "admission deadlock: queue non-empty, no active "
                    "sequences, and prefill still cannot get blocks")
            else:
                # everything admitted this step retired at its first
                # token (or the admission cap paused the queue)
                ret = bool(self.queue) or admitted > 0 or completed > 0
            self._close_step(tr, False, admitted, completed, executed,
                             t0, t1, t2, t2)
            return ret
        self._grow_or_preempt()
        if not self.active:                # everything deferred; retry
            self._close_step(tr, False, admitted, completed, executed,
                             t0, t1, t2, t2)
            return bool(self.queue or self.prefilling)
        S = self.max_slots
        tokens = np.zeros(S, np.int32)
        positions = np.zeros(S, np.int32)
        temps = np.ones(S, np.float32)
        greedy = np.zeros(S, bool)
        for slot, st in self.active.items():
            tokens[slot] = st.emitted[-1]
            positions[slot] = st.pos
            temps[slot] = st.request.params.temperature
            greedy[slot] = st.request.params.greedy
        logits = self.engine.decode_once(tokens, positions)
        if prof is not None:
            # deliberate: profiler-gated phase edge (see _admit edge)
            jax.block_until_ready(logits)  # repro-lint: disable=RL001
        t3 = tr.clock()
        toks = self.engine.sample_tokens(logits, temps, greedy)
        # per-tenant inter-token gaps: record before retirement pops the
        # rows' last-token timestamps
        tr.decode_tokens([st.rid for st in self.active.values()])
        for slot in list(self.active):
            st = self.active[slot]
            st.pos += 1
            tok = int(toks[slot])
            st.emitted.append(tok)
            if tr.enabled:
                tr.decode(st.rid, st.pos - 1, tok)
            self._maybe_retire(st, tok)
        self._close_step(tr, True, admitted, completed, executed,
                         t0, t1, t2, t3)
        return True

    def run(self) -> None:
        """Run until the queue and all slots are empty."""
        while self.has_work:
            self.step()

    def drain(self) -> None:
        """Graceful drain: close admission, finish all in-flight work."""
        self.draining = True
        self.run()

    def abort(self) -> List[_ReqState]:
        """Failover salvage: cancel every in-flight cursor, free every
        live slot, release every prefix pin, close admission, and return
        the orphaned request states — in-flight first (oldest admission
        first), then queue order — so a gateway can re-route them with
        their emitted-so-far tokens (the recompute-preemption resume).

        Engine-side frees are best-effort: a crashed capsule's pool dies
        with the process anyway, but the request-side bookkeeping (the
        states, their emitted tokens) must survive regardless."""
        pc = self.prefix_cache
        inflight = sorted(list(self.prefilling.values())
                          + list(self.active.values()),
                          key=lambda s: s.admit_seq)
        mid_prefill_slots = set(self.prefilling)
        salvaged: List[_ReqState] = []
        for st in inflight:
            try:
                if st.slot in mid_prefill_slots:
                    self.engine.cancel_prefill(st.slot)
                else:
                    self.engine.free_slot(st.slot)
            except Exception:   # noqa: BLE001 — dead capsule: its pool
                pass            # died with it; nothing left to free
            if pc is not None and st.prefix_blocks:
                try:
                    pc.release(st.prefix_blocks)
                except Exception:   # noqa: BLE001 — same: best-effort
                    pass
            st.prefix_blocks = []
            self.tracer.unbind_slot(st.slot)
            st.slot = -1
            st.cached_len = 0
            st.inflight_seq = None
            salvaged.append(st)
        self.prefilling.clear()
        self.active.clear()
        salvaged.extend(self.queue)
        self.queue.clear()
        self.draining = True
        return salvaged

    # -- results -------------------------------------------------------------

    def output(self, rid: int) -> np.ndarray:
        return np.asarray(self.done[rid].emitted, np.int32)

    def finish_reason(self, rid: int) -> str:
        return self.done[rid].finish_reason
