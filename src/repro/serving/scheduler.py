"""Continuous-batching scheduler: admission queue + slot manager.

One scheduler drives one :class:`~repro.serving.engine.ServingEngine`
(conceptually: the serving process inside one ``ch-run`` capsule).  The
loop is the standard continuous-batching shape:

    admit:  drain the queue into free slots in *batches*: as many
            queued prompts as slots, KV blocks, and the engine's
            ``prefill_batch`` allow are co-prefilled through ONE
            compiled chunked program per round
            (``engine.prefill_into_slots``); each request's prefix-cache
            probe still runs first so only uncached suffixes execute,
            and all first tokens of a batch are sampled in one
            vectorized call (TTFT = one shared batched prefill instead
            of a serial train of them);
    decode: one ``decode_once`` over the pooled cache advances *every*
            live sequence by one token, each sampled with its own
            ``SamplingParams``;
    retire: a sequence that hits its own ``max_new_tokens`` or emits its
            ``eos_token`` leaves immediately — its KV blocks return to
            the ring, its prefix-block pins are released, and the slot
            is refilled on the next admit, mid-decode of the others.

Prefix-cache interplay: the matched blocks are pinned (refcounted) for
the request's lifetime so LRU eviction can never reclaim KV a live
sequence was served from, and every admitted prompt is inserted back
into the radix tree right after its prefill, making its KV available to
the next request that shares it.  Co-admission respects this: a queued
request sharing at least one full KV block of prefix with a request
already collected into the current batch is deferred one round, so it
admits *after* the insert and HITs the shared prefix instead of
recomputing it in parallel — shared-prefix bursts serialize (each later
request then skips the shared compute), unrelated prompts batch.

With a paged engine the KV pool can be sized below worst case, so
``OutOfBlocks`` is a real event on both sides of the loop and neither
may lose a request:

    admission — the head request stays in the queue until its prefill
        blocks actually allocate; on ``OutOfBlocks`` its prefix pins are
        released, it returns to the *head*, and admission stops for the
        round (a retirement must free blocks first);
    decode    — when a live sequence cannot grow by one block, the most
        recently admitted *other* sequence is preempted (slot and blocks
        freed, prefix pins released, request re-queued at the head); a
        preempted request resumes later by re-prefilling its prompt plus
        the tokens it already emitted — recompute-style preemption, so
        no KV swap space is needed and greedy outputs are unchanged.

This replaces the seed engine's run-everything-to-the-global-max loop:
short requests stop costing decode work the step they finish, and
``decode_steps`` accounting makes the saving testable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import Request, ServingEngine
from repro.serving.kvcache import OutOfBlocks
from repro.serving.metrics import ServingMetrics


@dataclass
class _ReqState:
    rid: int
    request: Request
    slot: int = -1
    pos: int = 0                       # next cache write position
    admit_seq: int = -1                # admission-recency (victim pick)
    emitted: List[int] = field(default_factory=list)
    finish_reason: str = ""
    cached_len: int = 0                # tokens served from the prefix cache
    prefix_blocks: List[int] = field(default_factory=list)   # pinned blocks


class Scheduler:
    """Admission queue + continuous-batching slot manager for one engine."""

    def __init__(self, engine: ServingEngine,
                 metrics: Optional[ServingMetrics] = None,
                 clock=time.perf_counter,
                 max_admissions_per_step: Optional[int] = None):
        self.engine = engine
        self.max_slots = engine.max_slots
        # cap on requests admitted per scheduler step (None = drain all
        # that fit).  1 reproduces the old one-at-a-time admission — the
        # benchmark baseline — and smooths decode latency under bursts.
        self.max_admissions_per_step = max_admissions_per_step
        self.metrics = metrics or ServingMetrics(clock=clock)
        self.queue: deque = deque()
        self.active: Dict[int, _ReqState] = {}          # slot -> state
        self.done: Dict[int, _ReqState] = {}            # rid  -> state
        self.draining = False
        self.preemptions = 0               # decode-time OutOfBlocks defers
        self.admission_stalls = 0          # admit-time OutOfBlocks retries
        self._next_rid = 0
        self._admit_counter = 0            # monotonic admission stamp
        # eviction counting is per-scheduler; the cache outlives us
        pc = engine.prefix_cache
        self._evict_base = pc.stats.evicted_blocks if pc else 0

    @property
    def decode_steps(self) -> int:
        return self.metrics.decode_steps

    @property
    def prefix_cache(self):
        return self.engine.prefix_cache

    # -- submission ----------------------------------------------------------

    def submit(self, request: Request) -> int:
        if self.draining:
            raise RuntimeError("scheduler is draining; admission closed")
        if len(request.prompt) == 0:
            raise ValueError(
                "empty prompt: a request needs at least one token "
                "(the first sample comes from the prefill logits)")
        sp = request.params
        need = len(request.prompt) + sp.max_new_tokens
        if need > self.engine.max_seq_len:
            raise ValueError(
                f"prompt ({len(request.prompt)}) + max_new_tokens "
                f"({sp.max_new_tokens}) exceeds max_seq_len "
                f"({self.engine.max_seq_len})")
        kv = self.engine.kv
        if kv._blocks_for(need) > kv.pool.num_blocks:
            raise ValueError(
                f"request needs {kv._blocks_for(need)} KV blocks at full "
                f"length but the pool holds {kv.pool.num_blocks}; it could "
                "never be scheduled even alone")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(_ReqState(rid, request))
        self.metrics.record_submit(rid)
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    @property
    def load(self) -> int:
        return len(self.queue) + len(self.active)

    def prefix_match_len(self, prompt: np.ndarray) -> int:
        """Longest cached prefix this replica holds (gateway affinity)."""
        pc = self.prefix_cache
        return pc.peek(prompt) if pc is not None else 0

    # -- the loop ------------------------------------------------------------

    def _shares_block(self, a: np.ndarray, b: np.ndarray) -> bool:
        """True when two prompts share at least one full KV block of
        common prefix — i.e. co-admitting them would recompute KV the
        prefix cache could have shared."""
        n = min(len(a), len(b), self.engine.kv.block_size)
        return (n == self.engine.kv.block_size
                and bool(np.array_equal(a[:n], b[:n])))

    def _collect_batch(self, limit: int):
        """Pop as many admissible head-of-queue requests as slots, KV
        blocks, and ``limit`` allow.  Prefix pins are taken here; the
        caller must release them if the prefill never happens.  Returns
        ``(states, seqs, starts, blocks_lists)`` in queue order."""
        kv = self.engine.kv
        pc = self.prefix_cache
        states, seqs, starts, blocks_lists = [], [], [], []
        blocks_needed = 0
        while (self.queue and len(states) < limit
               and len(states) < kv.free_slot_count):
            st = self.queue[0]
            req = st.request
            if req.params.max_new_tokens <= 0:      # nothing to generate
                self.queue.popleft()
                st.finish_reason = "length"
                self.done[st.rid] = st
                self.metrics.record_finish(st.rid, 0, "length")
                continue
            resumed = bool(st.emitted)              # preempted earlier
            # a resumed request re-prefills prompt + all emitted tokens
            # except the last, which is still waiting to be fed to decode
            seq = (req.prompt if not resumed else
                   np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(st.emitted[:-1], np.int32)]))
            if kv.pool.available < blocks_needed + kv._blocks_for(len(seq)):
                # KV pool dry for THIS request: stop collecting BEFORE
                # touching the prefix cache so a request parked at the
                # head doesn't re-count lookup stats (or churn pins)
                # once per retry; stall only if nothing at all fit
                if not states:
                    self.admission_stalls += 1
                break
            if pc is not None and any(
                    self._shares_block(seq, s) for s in seqs):
                # the candidate shares >= one KV block of prefix with a
                # request already in this batch: defer it one round so
                # it can HIT the prefix the earlier request is about to
                # insert instead of recomputing it in parallel —
                # shared-prefix bursts serialize, unrelated prompts batch
                break
            cached_len, blocks = (0, [])
            if pc is not None:
                cached_len, blocks = pc.lookup(seq)
            self.queue.popleft()
            st.cached_len, st.prefix_blocks = cached_len, blocks
            states.append(st)
            seqs.append(seq)
            starts.append(cached_len)
            blocks_lists.append(blocks)
            blocks_needed += kv._blocks_for(len(seq))
        return states, seqs, starts, blocks_lists

    def _admit(self) -> int:
        """Batched admission; returns how many requests were admitted
        (the step loop uses this to tell a capped-but-progressing round
        from a genuine admission deadlock)."""
        admitted = 0
        pc = self.prefix_cache
        while self.queue and self.engine.kv.free_slot_count > 0:
            limit = self.engine.prefill_batch
            if self.max_admissions_per_step is not None:
                limit = min(limit, self.max_admissions_per_step - admitted)
            if limit <= 0:
                return admitted
            states, seqs, starts, blocks_lists = self._collect_batch(limit)
            if not states:
                return admitted
            real0 = self.engine.prefill_tokens
            exec0 = self.engine.prefill_tokens_executed
            try:
                results = self.engine.prefill_into_slots(
                    seqs, [st.request.encoder_input for st in states],
                    start_pos=starts, prefix_blocks=blocks_lists)
            except Exception as e:
                # never lose a request or its pins: the engine released
                # every slot (all-or-nothing), so requeue the whole
                # batch at the head, in order.  OutOfBlocks (unreachable
                # given the pre-check) stalls; anything else — device
                # OOM, an engine assert — propagates with the scheduler
                # state intact, so the caller can retry or drain.
                for st, blocks in zip(reversed(states),
                                      reversed(blocks_lists)):
                    if pc is not None and blocks:
                        pc.release(blocks)
                    st.prefix_blocks = []
                    self.queue.appendleft(st)
                if not isinstance(e, OutOfBlocks):
                    raise
                self.admission_stalls += 1
                return admitted
            admitted += len(states)
            fresh: List[_ReqState] = []
            fresh_logits: List[np.ndarray] = []
            for st, seq, (slot, last_logits) in zip(states, seqs, results):
                resumed = bool(st.emitted)
                st.slot = slot
                st.admit_seq = self._admit_counter
                self._admit_counter += 1
                if pc is not None:
                    pc.insert(seq, st.slot)
                    if not resumed:        # one prefix outcome per request
                        self.metrics.record_prefix(st.cached_len, len(seq))
                    self.metrics.prefix_evictions = (pc.stats.evicted_blocks
                                                     - self._evict_base)
                st.pos = len(seq)
                if resumed:                         # last token still pending
                    self.active[st.slot] = st
                else:
                    fresh.append(st)
                    fresh_logits.append(np.asarray(last_logits))
            if fresh:
                # every first token of the batch in one vectorized sample
                toks = self.engine.sample_tokens(
                    np.stack(fresh_logits),
                    np.asarray([st.request.params.temperature
                                for st in fresh], np.float32),
                    np.asarray([st.request.params.greedy for st in fresh]))
                for st, tok in zip(fresh, toks):
                    tok = int(tok)
                    st.emitted.append(tok)
                    self.metrics.record_first_token(st.rid)
                    if not self._maybe_retire(st, tok):
                        self.active[st.slot] = st
            self.metrics.record_prefill_work(
                self.engine.prefill_tokens - real0,
                self.engine.prefill_tokens_executed - exec0)
        return admitted

    def _preempt(self, st: _ReqState) -> None:
        """Defer a live request: free its slot and KV blocks, release its
        prefix pins, and put it back at the head of the queue.  It will
        resume by re-prefilling prompt + emitted tokens (recompute-style
        preemption) once blocks are available again."""
        self.active.pop(st.slot, None)
        self.engine.free_slot(st.slot)
        if st.prefix_blocks:
            self.prefix_cache.release(st.prefix_blocks)
            st.prefix_blocks = []
        st.slot = -1
        self.queue.appendleft(st)
        self.preemptions += 1

    def _pick_victim(self, exclude_slot: int) -> Optional[_ReqState]:
        """Most recently *admitted* live request other than the one
        trying to grow — freeing the youngest admission wastes the least
        finished work.  (Admission recency, not rid: a resumed old
        request is younger than a long-running new one.)"""
        candidates = [st for slot, st in self.active.items()
                      if slot != exclude_slot]
        return (max(candidates, key=lambda st: st.admit_seq)
                if candidates else None)

    def _maybe_retire(self, st: _ReqState, tok: int) -> bool:
        sp = st.request.params
        reason = ""
        if len(st.emitted) >= sp.max_new_tokens:
            reason = "length"
        elif sp.eos_token is not None and tok == sp.eos_token:
            reason = "eos"
        if not reason:
            return False
        st.finish_reason = reason
        self.active.pop(st.slot, None)
        self.engine.free_slot(st.slot)
        if st.prefix_blocks:
            self.prefix_cache.release(st.prefix_blocks)
            st.prefix_blocks = []
        self.done[st.rid] = st
        self.metrics.record_finish(st.rid, len(st.emitted), reason)
        return True

    def _grow_or_preempt(self) -> None:
        """Back every live sequence's next token position with a block.
        When the pool is dry, preempt the youngest other request and
        retry; a sequence with nobody left to evict defers itself (it
        can always fit alone later — submit() guarantees that)."""
        for slot in sorted(self.active):
            st = self.active.get(slot)
            if st is None:                 # preempted earlier this pass
                continue
            while True:
                try:
                    self.engine.kv.ensure_capacity(slot, st.pos + 1)
                    break
                except OutOfBlocks:
                    victim = self._pick_victim(exclude_slot=slot)
                    self._preempt(victim if victim is not None else st)
                    if victim is None:
                        break              # st itself deferred; move on

    def step(self) -> bool:
        """Admit into free slots, then decode one token for every live
        sequence.  Returns False when there was nothing to do."""
        admitted = self._admit()
        if not self.active:
            if self.queue and not admitted:
                # nothing live, nothing admitted: with the pool idle this
                # is unservable demand, not a transient — fail loudly
                # instead of spinning forever
                raise RuntimeError(
                    "admission deadlock: queue non-empty, no active "
                    "sequences, and prefill still cannot get blocks")
            # everything admitted this step retired at its first token
            # (or the admission cap paused the queue): not a deadlock
            return bool(self.queue) or admitted > 0
        self._grow_or_preempt()
        if not self.active:
            return bool(self.queue)        # everything deferred; retry
        S = self.max_slots
        tokens = np.zeros(S, np.int32)
        positions = np.zeros(S, np.int32)
        temps = np.ones(S, np.float32)
        greedy = np.zeros(S, bool)
        for slot, st in self.active.items():
            tokens[slot] = st.emitted[-1]
            positions[slot] = st.pos
            temps[slot] = st.request.params.temperature
            greedy[slot] = st.request.params.greedy
        logits = self.engine.decode_once(tokens, positions)
        toks = self.engine.sample_tokens(logits, temps, greedy)
        for slot in list(self.active):
            st = self.active[slot]
            st.pos += 1
            tok = int(toks[slot])
            st.emitted.append(tok)
            self._maybe_retire(st, tok)
        self.metrics.sample_gauges(len(self.queue), len(self.active),
                                   self.max_slots)
        return True

    def run(self) -> None:
        """Run until the queue and all slots are empty."""
        while self.has_work:
            self.step()

    def drain(self) -> None:
        """Graceful drain: close admission, finish all in-flight work."""
        self.draining = True
        self.run()

    # -- results -------------------------------------------------------------

    def output(self, rid: int) -> np.ndarray:
        return np.asarray(self.done[rid].emitted, np.int32)

    def finish_reason(self, rid: int) -> str:
        return self.done[rid].finish_reason
