from repro.serving.engine import (PrefillCursor, Request, SamplingParams,
                                  ServingEngine, make_serve_step)
from repro.serving.fabric import (CapacityError, ClusterRegistry,
                                  LocalProcessBackend, Mailbox,
                                  MailboxError, MockBackend,
                                  RemoteScheduler, ReplicaWorker,
                                  SchedulerBackend, SlurmBackend,
                                  WorkerSpec, collect_fabric_traces,
                                  launch_fabric_replicas,
                                  shutdown_fabric)
from repro.serving.faults import (FAULT_KINDS, FAULT_SITES, FaultInjector,
                                  FaultPlan, FaultSpec, InjectedFault,
                                  ReplicaCrashed)
from repro.serving.gateway import (CapsuleReplica, DegradationPolicy,
                                   Overloaded, ReplicaGateway,
                                   RequestFailed, RetryPolicy,
                                   launch_capsule_replicas)
from repro.serving.health import (DEAD, DEGRADED, HEALTHY, QUARANTINED,
                                  HealthConfig, HealthMonitor)
from repro.serving.kvcache import KVBlockPool, OutOfBlocks, PagedKVCache
from repro.serving.metrics import (ServingMetrics, atomic_write_json,
                                   merge_summaries)
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.profiling import (RecompilationTracker, StepProfiler,
                                     profile_kernel, profile_paged_kernels)
from repro.serving.scheduler import Scheduler
from repro.serving.slo import (SLOConfig, SLOMonitor, SLOPolicy,
                               SlidingWindow, TenantStats,
                               merge_tenant_summaries,
                               merge_window_summaries)
from repro.serving.tracing import (EVENT_KINDS, FAULT_EVENT_KINDS, Tracer,
                                   export_chrome_trace, export_jsonl,
                                   merge_traces, to_chrome_trace,
                                   validate_event)
