from repro.serving.engine import Request, SamplingParams, ServingEngine, make_serve_step
