from repro.serving.engine import (PrefillCursor, Request, SamplingParams,
                                  ServingEngine, make_serve_step)
from repro.serving.gateway import (CapsuleReplica, ReplicaGateway,
                                   launch_capsule_replicas)
from repro.serving.kvcache import KVBlockPool, OutOfBlocks, PagedKVCache
from repro.serving.metrics import (ServingMetrics, atomic_write_json,
                                   merge_summaries)
from repro.serving.prefix_cache import PrefixCache, PrefixCacheStats
from repro.serving.profiling import (RecompilationTracker, StepProfiler,
                                     profile_kernel, profile_paged_kernels)
from repro.serving.scheduler import Scheduler
from repro.serving.slo import (SLOConfig, SLOMonitor, SLOPolicy,
                               SlidingWindow, TenantStats,
                               merge_tenant_summaries,
                               merge_window_summaries)
from repro.serving.tracing import (EVENT_KINDS, Tracer, export_chrome_trace,
                                   export_jsonl, merge_traces,
                                   to_chrome_trace, validate_event)
