"""Replica health tracking for the serving gateway.

One :class:`HealthMonitor` per replica, fed by the gateway after every
gateway step with the one signal a wedged capsule cannot fake: *did the
scheduler's observable state change* (progress signature), plus any
exception ``step()`` raised.  The state machine is the usual membership
ladder —

    HEALTHY -> DEGRADED -> QUARANTINED        (consecutive bad steps)
    any     -> DEAD                           (fatal error, permanent)
    DEGRADED -> HEALTHY                       (progress resumed)
    QUARANTINED -> HEALTHY                    (rejoin after cooldown)

— and every transition is **edge-triggered**: ``record_step`` /
``record_failure`` return a transition dict exactly when the state
changed (the gateway turns it into one ``replica_health`` trace event),
never a per-step alarm flood.  DEAD is terminal for automatic handling:
a crashed capsule does not flap back; only an explicit gateway
``rejoin`` (the capsule-relaunch path) revives a QUARANTINED replica.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

HEALTHY = "healthy"
DEGRADED = "degraded"
QUARANTINED = "quarantined"
DEAD = "dead"

HEALTH_STATES = (HEALTHY, DEGRADED, QUARANTINED, DEAD)


@dataclass(frozen=True)
class HealthConfig:
    """Thresholds in *consecutive bad gateway steps* (a bad step is an
    exception or a no-progress step while work was pending)."""
    degraded_after: int = 2        # HEALTHY -> DEGRADED
    quarantine_after: int = 4      # DEGRADED -> QUARANTINED
    rejoin_cooldown_steps: int = 8   # QUARANTINED -> rejoin eligibility
    auto_rejoin: bool = True

    def __post_init__(self):
        if self.degraded_after <= 0 or self.quarantine_after <= 0:
            raise ValueError("health thresholds must be positive")
        if self.quarantine_after <= self.degraded_after:
            raise ValueError(
                f"quarantine_after ({self.quarantine_after}) must exceed "
                f"degraded_after ({self.degraded_after})")
        if self.rejoin_cooldown_steps < 0:
            raise ValueError("rejoin_cooldown_steps must be >= 0")


class HealthMonitor:
    """Edge-triggered per-replica health state machine."""

    def __init__(self, config: Optional[HealthConfig] = None):
        self.config = config or HealthConfig()
        self.state = HEALTHY
        self.consecutive_bad = 0
        self.failures = 0              # exceptions observed (all-time)
        self.stalls = 0                # no-progress steps (all-time)
        self.rejoins = 0
        self.last_error = ""
        self.transitions: List[Dict[str, object]] = []

    @property
    def routable(self) -> bool:
        """May receive new work (QUARANTINED/DEAD replicas may not)."""
        return self.state in (HEALTHY, DEGRADED)

    @property
    def alive(self) -> bool:
        return self.state != DEAD

    # -- observations --------------------------------------------------------

    def record_step(self, made_progress: bool
                    ) -> Optional[Dict[str, object]]:
        """One gateway step on a routable replica with pending work:
        returns the transition this observation caused, or None."""
        if made_progress:
            self.consecutive_bad = 0
            if self.state == DEGRADED:
                return self._to(HEALTHY, "progress_resumed")
            return None
        self.stalls += 1
        return self._bad("no_progress")

    def record_failure(self, error: str, fatal: bool = False
                       ) -> Optional[Dict[str, object]]:
        """``step()`` raised.  ``fatal`` (a crashed capsule) goes
        straight to DEAD; transient errors climb the ladder."""
        self.failures += 1
        self.last_error = error
        if fatal:
            return self._to(DEAD, f"crashed: {error}")
        return self._bad(f"step_error: {error}")

    def mark_rejoined(self) -> Dict[str, object]:
        """The gateway relaunched this (QUARANTINED) replica."""
        assert self.state == QUARANTINED, \
            f"rejoin from {self.state}, expected {QUARANTINED}"
        self.rejoins += 1
        self.consecutive_bad = 0
        tr = self._to(HEALTHY, "rejoin")
        assert tr is not None
        return tr

    # -- internals -----------------------------------------------------------

    def _bad(self, reason: str) -> Optional[Dict[str, object]]:
        self.consecutive_bad += 1
        cfg = self.config
        if (self.state == HEALTHY
                and self.consecutive_bad >= cfg.degraded_after):
            return self._to(DEGRADED, reason)
        if (self.state == DEGRADED
                and self.consecutive_bad >= cfg.quarantine_after):
            return self._to(QUARANTINED, reason)
        return None

    def _to(self, new: str, reason: str) -> Optional[Dict[str, object]]:
        if new == self.state:
            return None
        tr = {"from": self.state, "to": new, "reason": reason,
              "consecutive_bad": self.consecutive_bad}
        self.state = new
        self.transitions.append(tr)
        return tr

    def summary(self) -> Dict[str, object]:
        return {"state": self.state, "failures": self.failures,
                "stalls": self.stalls, "rejoins": self.rejoins,
                "transitions": len(self.transitions),
                "last_error": self.last_error}
