"""Prefix cache: a refcounted radix tree over shared KV blocks.

Real capsule-fleet traffic is dominated by shared prefixes — system
prompts, few-shot templates, the growing history of a multi-turn chat.
The serving engine's prefill replays every prompt token through
``decode_step``, so two requests sharing a 500-token system prompt used
to pay that prefill twice.  This module keeps the KV values of previously
served prompts resident in the :class:`~repro.serving.kvcache.PagedKVCache`
prefix store and indexes them with a radix tree over token ids, so
admission can skip straight to the first *uncached* token:

* **Radix index** — each edge is a run of token ids; a node's blocks are
  the prefix-store block ids holding the KV for the edge's positions.
  ``lookup`` walks the tree and returns the longest cached prefix plus
  the blocks backing it; ``insert`` extends the tree with a freshly
  prefilled prompt, snapshotting its KV out of the engine's pooled cache.
* **Reference counts** — a block is shared by the tree and by every
  in-flight request that loaded it; ``lookup`` pins the matched blocks
  (``KVBlockPool.ref``) until the request retires, so eviction can never
  reclaim KV a running sequence was served from.
* **Copy-on-write** — when a new branch diverges inside a block (a
  partially-filled tail, or a mid-block split), the shared block is
  forked (``PagedKVCache.fork_prefix_block``) so the diverging branch
  writes its own copy and never corrupts the positions other readers map.
  At a mid-edge split the spanning block is instead *shared* between the
  two halves with an extra reference — both sides agree on its common
  positions.
* **LRU eviction** — when the prefix pool runs dry, least-recently-used
  *unreferenced* leaf subtrees are unlinked and their exclusive blocks
  returned to the ring; pinned or shared blocks survive until their last
  reference drops.

Validity convention: a node's tokens define exactly which positions of
its blocks are meaningful (a tail block may be partial).  Matching never
reads past the matched token count, so no per-block length bookkeeping
is needed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kvcache import OutOfBlocks, PagedKVCache


def _common_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class _Node:
    """One radix edge: token run [start, end) + the blocks backing it.

    ``blocks[k]`` covers block index ``start // block_size + k``.  When
    ``start`` is not block-aligned, ``blocks[0]`` *overlaps* the parent's
    tail block index: it is a forked (or split-shared) copy that also
    holds the common positions below ``start``, and it supersedes the
    parent's block during a match through this node.
    """
    __slots__ = ("start", "tokens", "blocks", "children", "parent",
                 "last_used")

    def __init__(self, start: int, tokens: np.ndarray, blocks: List[int],
                 parent: Optional["_Node"]):
        self.start = start
        self.tokens = tokens
        self.blocks = blocks
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.last_used = 0

    @property
    def end(self) -> int:
        return self.start + len(self.tokens)


@dataclass
class PrefixCacheStats:
    hits: int = 0
    misses: int = 0
    cached_tokens_served: int = 0
    prompt_tokens_seen: int = 0
    inserted_blocks: int = 0
    forked_blocks: int = 0
    evicted_blocks: int = 0
    evicted_nodes: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate,
                "cached_tokens_served": self.cached_tokens_served,
                "prompt_tokens_seen": self.prompt_tokens_seen,
                "inserted_blocks": self.inserted_blocks,
                "forked_blocks": self.forked_blocks,
                "evicted_blocks": self.evicted_blocks,
                "evicted_nodes": self.evicted_nodes}


class PrefixCache:
    """Radix index over token-id prefixes backed by the KV prefix store."""

    def __init__(self, kv: PagedKVCache):
        assert kv.prefix_pool is not None, (
            "PagedKVCache built without prefix_blocks — pass "
            "prefix_blocks > 0 (and a family with a positional cache)")
        self.kv = kv
        self.pool = kv.prefix_pool
        self.block_size = kv.block_size
        self.root = _Node(0, np.empty(0, np.int32), [], None)
        self.stats = PrefixCacheStats()
        # bound by the scheduler (tracing.Tracer); insert/evict events
        self.tracer = None
        self._clock = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # -- matching --------------------------------------------------------

    def _walk(self, tokens: np.ndarray
              ) -> Tuple[int, Dict[int, int], List[_Node]]:
        """Longest-prefix walk.  Returns (matched token count, block-index
        -> block-id map for every block touching the match, path nodes)."""
        bs = self.block_size
        node, pos = self.root, 0
        blockmap: Dict[int, int] = {}
        path: List[_Node] = []
        while pos < len(tokens):
            child = node.children.get(int(tokens[pos]))
            if child is None:
                break
            m = _common_len(child.tokens, tokens[pos:])
            end = child.start + m
            bi0 = child.start // bs
            for k, b in enumerate(child.blocks):
                if (bi0 + k) * bs < end:   # block holds >=1 matched position
                    blockmap[bi0 + k] = b  # supersedes parent's overlap
            pos = end
            path.append(child)
            if m < len(child.tokens):
                break
            node = child
        return pos, blockmap, path

    def peek(self, tokens: np.ndarray) -> int:
        """Longest cached prefix length, with no side effects (used by the
        gateway for prefix-affinity routing)."""
        tokens = np.asarray(tokens, np.int32)
        matched, _, _ = self._walk(tokens)
        return min(matched, max(len(tokens) - 1, 0))

    def lookup(self, tokens: np.ndarray) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens`` usable for admission.

        Returns ``(cached_len, blocks)`` where ``blocks`` back positions
        ``[0, cached_len)`` in order.  The match is capped at
        ``len(tokens) - 1`` so at least one token always runs through
        prefill (the first sample needs its logits).  Matched blocks are
        pinned with one reference each — the caller must
        :meth:`release` them when the request retires.
        """
        tokens = np.asarray(tokens, np.int32)
        matched, blockmap, path = self._walk(tokens)
        matched = min(matched, len(tokens) - 1)
        self.stats.prompt_tokens_seen += len(tokens)
        if matched <= 0:
            self.stats.misses += 1
            return 0, []
        n_blocks = -(-matched // self.block_size)
        blocks = [blockmap[i] for i in range(n_blocks)]
        tick = self._tick()
        for node in path:
            node.last_used = tick
        for b in blocks:
            self.pool.ref(b)
        self.stats.hits += 1
        self.stats.cached_tokens_served += matched
        return matched, blocks

    def release(self, blocks: Sequence[int]) -> None:
        """Drop a request's pins; blocks evicted from the tree meanwhile
        return to the free ring here, at their last reference."""
        for b in blocks:
            self.pool.unref(b)

    # -- insertion -------------------------------------------------------

    def insert(self, tokens: np.ndarray, slot: int) -> int:
        """Index a freshly prefilled prompt sitting in pooled-cache
        ``slot`` (all positions ``[0, len(tokens))`` valid there).
        Snapshots the uncached suffix into newly allocated prefix blocks.
        Returns the number of new tokens cached (0 if already present or
        the pool is too pinned to make room)."""
        tokens = np.asarray(tokens, np.int32)
        bs = self.block_size
        node, pos = self.root, 0
        while pos < len(tokens):
            child = node.children.get(int(tokens[pos]))
            if child is None:
                return self._append_branch(node, tokens, pos, slot)
            m = _common_len(child.tokens, tokens[pos:])
            if m == len(child.tokens):
                pos += m
                node = child
                continue
            if pos + m == len(tokens):
                return 0                   # fully covered mid-edge
            top = self._split(child, m)
            return self._append_branch(top, tokens, top.end, slot)
        return 0                           # exact node boundary: covered

    def _split(self, child: _Node, m: int) -> _Node:
        """Split ``child``'s edge after ``m`` tokens; returns the new top
        half.  A block spanning the cut is shared by both halves (one
        extra reference) — its positions below the cut are their common
        prefix, those above belong to the bottom branch only."""
        bs = self.block_size
        p = child.start + m
        bi0 = child.start // bs
        n_top = -(-(p - bi0 * bs) // bs)   # blocks covering [start, p)
        top_blocks = child.blocks[:n_top]
        bottom_first = p // bs - bi0       # index of block covering p
        bottom_blocks = child.blocks[bottom_first:]
        if p % bs != 0:                    # spanning block shared
            self.pool.ref(child.blocks[bottom_first])
        bottom = _Node(p, child.tokens[m:], bottom_blocks, child)
        bottom.children = child.children
        for c in bottom.children.values():
            c.parent = bottom
        bottom.last_used = child.last_used
        child.tokens = child.tokens[:m]
        child.blocks = top_blocks
        child.children = {int(bottom.tokens[0]): bottom}
        return child

    def _append_branch(self, parent: _Node, tokens: np.ndarray, pos: int,
                       slot: int) -> int:
        """Hang a new leaf holding ``tokens[pos:]`` under ``parent``
        (``parent.end == pos``).  If ``pos`` falls inside a block, the
        parent's partial tail is copy-on-write forked so this branch owns
        every block it writes."""
        bs = self.block_size
        total = len(tokens)
        bi_first = pos // bs
        bi_last = (total - 1) // bs
        overlap = pos % bs != 0
        need = bi_last - bi_first + 1
        # never snapshot a window that would run past the cache extent
        while need and (bi_first + need) * bs > self.kv.max_seq_len:
            need -= 1
        if self.pool.available < need:
            # the branch point and its ancestors must survive the purge
            protect, n = set(), parent
            while n is not None:
                protect.add(id(n))
                n = n.parent
            self.evict(need - self.pool.available, protect=protect)
        # cache as many leading blocks as the pool can hold right now
        need = min(need, self.pool.available)
        if need <= 0:
            return 0
        blocks: List[int] = []
        for k in range(need):
            bi = bi_first + k
            if k == 0 and overlap:
                # COW: this branch gets its own block for the shared
                # partial tail.  Ledger fork only — the save below fills
                # the whole window from the slot (whose prefix positions
                # are bit-identical to the shared block), so the physical
                # copy of kv.fork_prefix_block would be dead work here.
                bid = self.pool.fork(parent.blocks[-1])
                self.stats.forked_blocks += 1
                self.kv.save_prefix_block(slot, bi * bs, into=bid)
            else:
                bid = self.kv.save_prefix_block(slot, bi * bs)
            blocks.append(bid)
        covered_end = min((bi_first + need) * bs, total)
        leaf = _Node(pos, tokens[pos:covered_end], blocks, parent)
        leaf.last_used = self._tick()
        parent.children[int(tokens[pos])] = leaf
        self.stats.inserted_blocks += len(blocks)
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.prefix_insert(slot, covered_end - pos, len(blocks))
        return covered_end - pos

    # -- eviction --------------------------------------------------------

    def _shared_with_parent(self, node: _Node, b: int) -> bool:
        return (node.parent is not None and node.parent.blocks
                and b == node.parent.blocks[-1])

    def _evictable(self, node: _Node) -> bool:
        """A leaf whose blocks nobody outside the tree references.  A
        block shared with the parent (split spanning block) carries the
        parent's reference too; anything above that is a running
        request's pin — the subtree is hot, leave it."""
        if node.children or node.parent is None:
            return False
        for b in node.blocks:
            expected = 2 if self._shared_with_parent(node, b) else 1
            if self.pool.refcount(b) > expected:
                return False
        return True

    def _leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif n is not self.root:
                out.append(n)
        return out

    def evict(self, need_blocks: int, protect: frozenset = frozenset()
              ) -> int:
        """Unlink least-recently-used unreferenced leaves until
        ``need_blocks`` blocks have returned to the free ring (or nothing
        evictable remains).  ``protect`` names nodes (by id) an in-flight
        insert is extending.  Returns the number of blocks actually
        freed."""
        freed = 0
        nodes0 = self.stats.evicted_nodes
        while freed < need_blocks:
            candidates = [n for n in self._leaves()
                          if id(n) not in protect and self._evictable(n)]
            if not candidates:
                break
            victim = min(candidates, key=lambda n: n.last_used)
            freed += self._remove(victim)
        tr = self.tracer
        if freed and tr is not None and tr.enabled:
            tr.prefix_evict(freed, self.stats.evicted_nodes - nodes0)
        return freed

    def _remove(self, node: _Node) -> int:
        freed = 0
        for b in node.blocks:
            if self.pool.unref(b) == 0:
                freed += 1
        parent = node.parent
        del parent.children[int(node.tokens[0])]
        self.stats.evicted_nodes += 1
        self.stats.evicted_blocks += freed
        return freed

    # -- introspection ---------------------------------------------------

    def num_nodes(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children.values())
        return n - 1                       # root doesn't count

    def cached_tokens(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.tokens)
            stack.extend(node.children.values())
        return n
