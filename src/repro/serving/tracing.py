"""Request-lifecycle tracing + engine timeline for the serving stack.

The source paper's deployments run inside network-isolated capsules on
secure HPC systems: no Prometheus endpoint to scrape, no Jaeger
collector to push to.  All observability therefore has to be
**file-based and self-contained** — a structured event log the operator
copies out of the allocation and inspects offline.  This module is that
subsystem, and it answers the question ``metrics.py``'s endpoint
aggregates cannot: not "what was p95 TTFT" but "*why* did request 17
stall for 40 steps" — was it an ``OutOfBlocks`` admission stall, a
recompute preemption, a cold prefix probe, or a replica whose prefill
budget sat idle.

Three layers:

* :class:`Tracer` — one per scheduler/replica.  Typed events (kinds in
  :data:`EVENT_KINDS`) appended to a bounded ring buffer
  (``buffer_events`` deep; oldest events drop first, ``dropped_events``
  counts them) with a shared monotonic clock.  **The tracer owns the
  replica's** :class:`~repro.serving.metrics.ServingMetrics` **and
  feeds it**: the scheduler records through tracer methods only, so
  there is exactly one recording path whether tracing is on or off.
  Off-by-default: a disabled tracer forwards to the metrics counters
  and skips event construction entirely — the hot-loop cost is one
  ``if self.enabled`` per call site.

* **Per-request spans** — ``submit`` → (``route``) → ``prefix_probe`` →
  ``admit`` (or ``admission_stall``) → one ``prefill_advance`` per
  chunk round the row executed (with executed-token counts) →
  ``first_token`` → one ``decode`` per decode step → any
  ``preempt`` / re-``admit`` (``resumed=True``) cycles → ``retire``.
  Engine-side events carry slot ids; the tracer resolves them to
  request ids through the slot bindings the scheduler registers, so a
  span reads as one request even as it migrates across slots.

* **Engine step timeline** — one ``engine_step`` event per
  ``Scheduler.step()`` with the phase breakdown (admission /
  prefill-advance / decode dispatch / sample+retire, in seconds) and a
  gauges snapshot: free KV blocks, free slots, pinned prefix blocks,
  in-flight prefill cursors, queue depth, live sequences.

Exporters (files only, per the no-external-systems constraint):

* :meth:`Tracer.export_jsonl` / :func:`export_jsonl` — one JSON object
  per line; the schema every event obeys (checked by
  ``scripts/trace_report.py --validate``) is ``ts`` (float seconds,
  monotonic), ``kind`` (from :data:`EVENT_KINDS`), ``step`` (int; every
  event carries the engine step it happened in) and — for
  request-scoped kinds — ``rid``.
* :func:`to_chrome_trace` / :func:`export_chrome_trace` — Chrome
  trace-event format, loads directly in Perfetto or
  ``chrome://tracing``: each replica is a *process*, request spans are
  async lanes (``b``/``e`` with per-event ``n`` instants), engine-step
  phases are complete slices on an "engine" thread, and free-block /
  queue-depth gauges are counter tracks.
* :func:`merge_traces` — gateway-level merge: interleaves N replicas'
  ring buffers on the shared clock (every tracer in one gateway uses
  the same ``clock``), stamping each event with its replica name, so a
  cross-replica routing decision and the admission it caused line up in
  one timeline.
"""
from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

from repro.serving.metrics import ServingMetrics
from repro.serving.slo import SLOMonitor

# Fault-tolerance vocabulary (PR 9): replica membership transitions,
# failover/retry bookkeeping, and the degradation ladder.  Split out so
# ``scripts/trace_report.py --faults`` and the lint rule can name the
# family; unioned into EVENT_KINDS below.
FAULT_EVENT_KINDS = frozenset({
    "replica_health",    # health state transition (edge-triggered)
    "replica_failover",  # a dead/quarantined replica's work was salvaged
    "replica_retry",     # one salvaged request re-submitted elsewhere
    "replica_rejoin",    # quarantine exit: capsule relaunched, cache warm
    "request_failed",    # typed terminal failure (retry budget exhausted)
    "overload_shed",     # degradation ladder engaged/released (edge)
    "overload_cap",      # a request's max_new_tokens capped under load
})

# The documented event enum.  ``scripts/trace_report.py --validate``
# imports this set: an event whose ``kind`` is not listed here fails the
# schema check, so growing the vocabulary is an explicit, reviewed act.
EVENT_KINDS = frozenset({
    # request lifecycle
    "submit",            # rid entered a scheduler's queue
    "route",             # gateway picked a replica (reason + match len)
    "prefix_probe",      # admission-time radix lookup (hit/cached_len)
    "admit",             # slot claimed, cursor registered (resumed flag)
    "admission_stall",   # head-of-queue could not admit (OutOfBlocks)
    "prefill_advance",   # one chunk round's executed tokens for one row
    "first_token",       # prompt complete, first sample emitted
    "decode",            # one decode-step token for a live row
    "preempt",           # recompute preemption (mid_prefill flag)
    "retire",            # finished: tokens + reason
    # KV ledger
    "block_alloc",       # admission claimed blocks for a slot
    "block_grow",        # decode grew a slot by one block
    "block_free",        # slot retired, blocks back on the ring
    "out_of_blocks",     # pool dry (context: where it was hit)
    # prefix cache
    "prefix_insert",     # freshly prefilled prompt indexed into the tree
    "prefix_evict",      # LRU eviction freed blocks/nodes
    # engine timeline
    "engine_step",       # one Scheduler.step(): phases + gauges
    # observatory (PR 7): SLO + compilation telemetry
    "slo_breach",        # a tenant's policy check changed state
    "recompile",         # a jitted program saw a novel shape signature
}) | FAULT_EVENT_KINDS

# kinds that must carry a request id (the rest are step-scoped;
# prefill_advance / block events resolve rids through slot bindings and
# legitimately fall back to step scope when the engine is driven raw)
_RID_KINDS = frozenset({
    "submit", "route", "prefix_probe", "admit",
    "first_token", "decode", "preempt", "retire",
    "replica_retry", "request_failed", "overload_cap",
})

DEFAULT_BUFFER_EVENTS = 65536


class Tracer:
    """Per-replica event recorder that feeds the metrics counters.

    ``enabled=False`` (the default) keeps only the metrics path live:
    every recording method still forwards to :attr:`metrics`, but no
    event objects are built — the overhead over the pre-tracing code is
    one attribute check per call.  All tracers behind one gateway must
    share ``clock`` (they do by default: ``time.perf_counter`` is the
    process-wide monotonic clock) so :func:`merge_traces` can interleave
    them.
    """

    def __init__(self, metrics: Optional[ServingMetrics] = None, *,
                 enabled: bool = False,
                 buffer_events: int = DEFAULT_BUFFER_EVENTS,
                 clock=time.perf_counter, name: str = "replica0",
                 slo: Optional[SLOMonitor] = None):
        if buffer_events <= 0:
            raise ValueError(
                f"buffer_events must be positive, got {buffer_events}")
        self.metrics = metrics or ServingMetrics(clock=clock)
        self.slo = slo
        self.enabled = enabled
        self.clock = clock
        self.name = name
        self.events: deque = deque(maxlen=buffer_events)
        self.buffer_events = buffer_events
        self.emitted_events = 0            # incl. any the ring dropped
        self.current_step = 0              # stamped on every event
        self._slot_rid: Dict[int, int] = {}   # engine-side rid resolution

    @property
    def dropped_events(self) -> int:
        return self.emitted_events - len(self.events)

    # -- plumbing ------------------------------------------------------------

    def _emit(self, kind: str, rid: int = -1, **data) -> None:
        ev = {"ts": self.clock(), "kind": kind, "step": self.current_step}
        if rid >= 0:
            ev["rid"] = rid
        if data:
            ev.update(data)
        self.events.append(ev)
        self.emitted_events += 1

    def bind_slot(self, slot: int, rid: int) -> None:
        """Register slot -> rid so engine/kv events resolve to a span."""
        self._slot_rid[slot] = rid

    def unbind_slot(self, slot: int) -> None:
        self._slot_rid.pop(slot, None)

    def rid_of_slot(self, slot: int) -> int:
        return self._slot_rid.get(slot, -1)

    # -- request lifecycle (metrics-feeding sites first) ---------------------

    def submit(self, rid: int, tenant: str = "default",
               retry: bool = False) -> None:
        """``retry=True`` marks a failover re-submission: the metrics
        record a retry counter instead of a second logical submit, so
        merged fleet summaries count the request once (the ``retry``
        flag is only stamped on retry events, keeping pre-existing
        traces byte-identical)."""
        self.metrics.record_submit(rid, tenant, retry=retry)
        if self.enabled:
            if retry:
                self._emit("submit", rid, tenant=tenant, retry=True)
            else:
                self._emit("submit", rid, tenant=tenant)

    def first_token(self, rid: int) -> None:
        self.metrics.record_first_token(rid)
        if self.enabled:
            self._emit("first_token", rid)

    def retire(self, rid: int, n_tokens: int, reason: str) -> None:
        self.metrics.record_finish(rid, n_tokens, reason)
        if self.enabled:
            self._emit("retire", rid, n_tokens=n_tokens, reason=reason)

    def prefix_probe(self, rid: int, cached_len: int, prompt_len: int,
                     count: bool = True) -> None:
        """Admission-time prefix outcome.  ``count=False`` suppresses
        the metrics update (a resumed request's re-probe is a real trace
        event but must not double-count the per-request hit/miss)."""
        if count:
            self.metrics.record_prefix(cached_len, prompt_len)
        if self.enabled:
            self._emit("prefix_probe", rid, cached_len=cached_len,
                       prompt_len=prompt_len, hit=cached_len > 0)

    def prefill_work(self, real: int, executed: int) -> None:
        self.metrics.record_prefill_work(real, executed)

    def budget_round(self, executed: int, budget: int) -> None:
        self.metrics.record_budget(executed, budget)

    def decode_tokens(self, rids) -> None:
        """One decode step emitted tokens for ``rids`` (per-tenant
        inter-token gap recording; metrics-only, no event)."""
        self.metrics.record_decode_tokens(rids)

    def check_slo(self) -> None:
        """Evaluate SLO policies against current per-tenant stats and
        emit one ``slo_breach`` event per state transition (enter-breach
        or recover).  Cheap when nothing changed; no-op without a
        monitor.  Breach totals accumulate on the monitor even when the
        tracer is disabled — policy accounting is not trace-gated."""
        if self.slo is None:
            return
        for t in self.slo.evaluate(self.metrics.tenants):
            if self.enabled:
                self._emit("slo_breach", tenant=t["tenant"],
                           metric=t["metric"], observed=t["observed"],
                           threshold=t["threshold"],
                           recovered=t["recovered"])

    # -- trace-only events ---------------------------------------------------

    def route(self, rid: int, replica: str, reason: str, match_len: int,
              load: int) -> None:
        if self.enabled:
            self._emit("route", rid, replica=replica, reason=reason,
                       match_len=match_len, load=load)

    def admit(self, rid: int, slot: int, seq_len: int, cached_len: int,
              resumed: bool) -> None:
        # queue wait (submit -> first admit) per request/tenant; the
        # metrics ignore re-admits after preemption
        self.metrics.record_admit(rid)
        if self.enabled:
            self._emit("admit", rid, slot=slot, seq_len=seq_len,
                       cached_len=cached_len, resumed=resumed)

    def admission_stall(self, reason: str, queue_depth: int,
                        rid: int = -1) -> None:
        if self.enabled:
            self._emit("admission_stall", rid, reason=reason,
                       queue_depth=queue_depth)

    def prefill_advance(self, slot: int, executed: int, pos: int,
                        total: int) -> None:
        """One chunk round's progress for one in-flight row (engine)."""
        if self.enabled:
            self._emit("prefill_advance", self.rid_of_slot(slot), slot=slot,
                       executed=executed, pos=pos, total=total)

    def decode(self, rid: int, pos: int, token: int) -> None:
        if self.enabled:
            self._emit("decode", rid, pos=pos, token=token)

    def preempt(self, rid: int, mid_prefill: bool) -> None:
        if self.enabled:
            self._emit("preempt", rid, mid_prefill=mid_prefill)

    # -- KV ledger -----------------------------------------------------------

    def block_alloc(self, slot: int, n_blocks: int, available: int) -> None:
        if self.enabled:
            self._emit("block_alloc", self.rid_of_slot(slot), slot=slot,
                       n_blocks=n_blocks, available=available)

    def block_grow(self, slot: int, available: int) -> None:
        if self.enabled:
            self._emit("block_grow", self.rid_of_slot(slot), slot=slot,
                       available=available)

    def block_free(self, slot: int, n_blocks: int, available: int) -> None:
        if self.enabled:
            self._emit("block_free", self.rid_of_slot(slot), slot=slot,
                       n_blocks=n_blocks, available=available)

    def out_of_blocks(self, context: str, slot: int = -1) -> None:
        if self.enabled:
            self._emit("out_of_blocks", self.rid_of_slot(slot),
                       context=context, slot=slot)

    # -- prefix cache --------------------------------------------------------

    def prefix_insert(self, slot: int, tokens_cached: int,
                      blocks: int) -> None:
        if self.enabled:
            self._emit("prefix_insert", self.rid_of_slot(slot), slot=slot,
                       tokens_cached=tokens_cached, blocks=blocks)

    def prefix_evict(self, blocks: int, nodes: int) -> None:
        if self.enabled:
            self._emit("prefix_evict", blocks=blocks, nodes=nodes)

    # -- compilation telemetry -----------------------------------------------

    def recompile(self, program: str, signature: str, compiles: int,
                  post_warm: bool) -> None:
        """A jitted program compiled a novel shape signature beyond its
        first (or any signature after warmup) — the shape-churn warning
        :class:`~repro.serving.profiling.RecompilationTracker` raises."""
        if self.enabled:
            self._emit("recompile", program=program, signature=signature,
                       compiles=compiles, post_warm=post_warm)

    # -- fault tolerance (PR 9) ----------------------------------------------

    def replica_health(self, replica: str, old: str, new: str,
                       reason: str, consecutive_bad: int) -> None:
        """One edge-triggered membership transition (HEALTHY ->
        DEGRADED -> QUARANTINED / DEAD and back)."""
        if self.enabled:
            self._emit("replica_health", replica=replica, old=old,
                       new=new, reason=reason,
                       consecutive_bad=consecutive_bad)

    def failover(self, replica: str, salvaged_inflight: int,
                 salvaged_queued: int, reason: str) -> None:
        """A replica left the routable set and the gateway harvested
        its queued + in-flight requests for re-routing."""
        if self.enabled:
            self._emit("replica_failover", replica=replica,
                       salvaged_inflight=salvaged_inflight,
                       salvaged_queued=salvaged_queued, reason=reason)

    def retry(self, rid: int, attempt: int, backoff_steps: int,
              prev_replica: str) -> None:
        """One salvaged request re-submitted on this replica (``rid`` is
        its rid *here*; the submit/finish counters are handled by
        ``submit(retry=True)``, this is the trace-side marker)."""
        if self.enabled:
            self._emit("replica_retry", rid, attempt=attempt,
                       backoff_steps=backoff_steps,
                       prev_replica=prev_replica)

    def rejoin(self, replica: str, rejoins: int,
               warm_prefix_blocks: int) -> None:
        """Quarantine exit: the capsule relaunched; its engine-held
        prefix cache survived, so re-routed prompts probe warm."""
        if self.enabled:
            self._emit("replica_rejoin", replica=replica, rejoins=rejoins,
                       warm_prefix_blocks=warm_prefix_blocks)

    def request_failed(self, rid: int, reason: str, attempts: int) -> None:
        """Terminal typed failure: the request exhausted its retry
        budget (or had no replica left).  Feeds the failure counters —
        a failed request is *not* a completed one."""
        self.metrics.record_failed(reason)
        if self.enabled:
            self._emit("request_failed", rid, reason=reason,
                       attempts=attempts)

    def shed(self, tenant: str) -> None:
        """A submit was rejected (``Overloaded``) while degraded.  There
        is no rid (admission never happened) so this is metrics-only."""
        self.metrics.record_shed(tenant)

    def overload(self, active: bool, reason: str,
                 queue_depth: int) -> None:
        """Degradation-ladder edge: engaged (``active=True``) or
        released.  One event per transition, like ``slo_breach``."""
        if self.enabled:
            self._emit("overload_shed", active=active, reason=reason,
                       queue_depth=queue_depth, recovered=not active)

    def overload_cap(self, rid: int, tenant: str, orig_max_new: int,
                     capped_max_new: int) -> None:
        """An over-budget tenant's request had max_new_tokens capped
        while the fleet was degraded."""
        if self.enabled:
            self._emit("overload_cap", rid, tenant=tenant,
                       orig_max_new=orig_max_new,
                       capped_max_new=capped_max_new)

    # -- engine timeline -----------------------------------------------------

    def engine_step(self, *, decoded: bool, queue_depth: int, active: int,
                    max_slots: int, admitted: int, completed: int,
                    prefill_executed: int, budget: Optional[int],
                    dur_admit_s: float, dur_prefill_s: float,
                    dur_decode_s: float, dur_sample_s: float,
                    free_blocks: int, free_slots: int, inflight: int,
                    prefix_pins: int) -> None:
        """Close one scheduler step: gauge sampling (decode steps only —
        the pre-tracing metrics semantics) plus the timeline event."""
        if decoded:
            self.metrics.sample_gauges(queue_depth, active, max_slots)
        if self.enabled:
            self._emit("engine_step", decoded=decoded,
                       queue_depth=queue_depth, active=active,
                       admitted=admitted, completed=completed,
                       prefill_executed=prefill_executed,
                       budget=budget if budget is not None else 0,
                       dur_admit_s=dur_admit_s,
                       dur_prefill_s=dur_prefill_s,
                       dur_decode_s=dur_decode_s,
                       dur_sample_s=dur_sample_s,
                       free_blocks=free_blocks, free_slots=free_slots,
                       inflight=inflight, prefix_pins=prefix_pins)
        self.current_step += 1

    # -- export --------------------------------------------------------------

    def snapshot(self) -> List[dict]:
        """The buffered events, oldest first (copies — safe to mutate)."""
        return [dict(ev) for ev in self.events]

    def export_jsonl(self, path) -> Path:
        return export_jsonl(self.snapshot(), path, replica=self.name)


# ---------------------------------------------------------------------------
# file exporters
# ---------------------------------------------------------------------------

def export_jsonl(events: Iterable[Mapping], path,
                 replica: Optional[str] = None) -> Path:
    """One JSON object per line.  ``replica`` stamps events that do not
    carry one already (merged streams do)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        for ev in events:
            if replica is not None and "replica" not in ev:
                ev = {**ev, "replica": replica}
            f.write(json.dumps(ev, sort_keys=True) + "\n")
    return path


def merge_traces(tracers: Sequence[Tracer]) -> List[dict]:
    """Gateway-level merge: interleave N replicas' buffers on the shared
    clock, each event stamped with its replica name.  The result is a
    single fleet-wide timeline — a ``route`` decision on one replica and
    the ``admit`` it produced sort adjacently by ``ts``."""
    merged: List[dict] = []
    for tr in tracers:
        for ev in tr.events:
            merged.append({**ev, "replica": tr.name})
    merged.sort(key=lambda ev: ev["ts"])
    return merged


def _span_bounds(evs: List[dict]) -> Dict[int, List[dict]]:
    by_rid: Dict[int, List[dict]] = {}
    for ev in evs:
        rid = ev.get("rid", -1)
        if rid >= 0:
            by_rid.setdefault(rid, []).append(ev)
    return by_rid


def to_chrome_trace(events_by_replica: Mapping[str, Sequence[Mapping]]
                    ) -> dict:
    """Chrome trace-event JSON (Perfetto / chrome://tracing).

    Layout: one *process* per replica; request spans as async lanes
    (``b``/``e`` pairs keyed by a per-replica string id, with ``n``
    instants for every intra-span event); engine-step phases as ``X``
    complete slices on an "engine" thread; free-block and queue-depth
    gauges as counter tracks.  Timestamps are microseconds relative to
    the earliest event across all replicas (the shared clock).
    """
    all_ts = [ev["ts"] for evs in events_by_replica.values() for ev in evs]
    t0 = min(all_ts) if all_ts else 0.0

    def us(ts: float) -> float:
        return (ts - t0) * 1e6

    out: List[dict] = []
    for pid, (replica, evs) in enumerate(sorted(events_by_replica.items())):
        evs = sorted(evs, key=lambda e: e["ts"])
        out.append({"ph": "M", "pid": pid, "name": "process_name",
                    "args": {"name": replica}})
        out.append({"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
                    "args": {"name": "requests"}})
        out.append({"ph": "M", "pid": pid, "tid": 1, "name": "thread_name",
                    "args": {"name": "engine"}})
        for rid, revs in sorted(_span_bounds(evs).items()):
            span_id = f"{replica}/req{rid}"
            name = f"req {rid}"
            # open at the first event of the span (submit unless the
            # ring dropped it), close at the last (retire when complete)
            base = {"cat": "request", "name": name, "id": span_id,
                    "pid": pid, "tid": 0}
            out.append({**base, "ph": "b", "ts": us(revs[0]["ts"])})
            for ev in revs:
                args = {k: v for k, v in ev.items()
                        if k not in ("ts", "rid")}
                out.append({**base, "ph": "n", "ts": us(ev["ts"]),
                            "args": args})
            out.append({**base, "ph": "e", "ts": us(revs[-1]["ts"])})
        for ev in evs:
            if ev["kind"] in ("slo_breach", "recompile", "replica_health",
                              "replica_failover", "replica_rejoin",
                              "overload_shed"):
                # step-scoped warnings: instants on the engine thread so
                # they line up with the phase slices they interrupt
                # (rid-carrying fault kinds — replica_retry,
                # request_failed, overload_cap — flow into their request
                # lanes via the span builder above instead)
                out.append({"ph": "i", "s": "t", "cat": "observatory",
                            "name": ev["kind"], "pid": pid, "tid": 1,
                            "ts": us(ev["ts"]),
                            "args": {k: v for k, v in ev.items()
                                     if k != "ts"}})
                continue
            if ev["kind"] != "engine_step":
                continue
            end = ev["ts"]
            phases = [("sample", ev.get("dur_sample_s", 0.0)),
                      ("decode", ev.get("dur_decode_s", 0.0)),
                      ("prefill", ev.get("dur_prefill_s", 0.0)),
                      ("admit", ev.get("dur_admit_s", 0.0))]
            for name, dur in phases:       # walk backwards from step end
                if dur <= 0.0:
                    continue
                out.append({"ph": "X", "cat": "engine", "name": name,
                            "pid": pid, "tid": 1,
                            "ts": us(end - dur), "dur": dur * 1e6,
                            "args": {"step": ev["step"]}})
                end -= dur
            out.append({"ph": "C", "pid": pid, "name": "free_blocks",
                        "ts": us(ev["ts"]),
                        "args": {"free_blocks": ev.get("free_blocks", 0)}})
            out.append({"ph": "C", "pid": pid, "name": "queue_depth",
                        "ts": us(ev["ts"]),
                        "args": {"queue_depth": ev.get("queue_depth", 0)}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(events_by_replica: Mapping[str, Sequence[Mapping]],
                        path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome_trace(events_by_replica)) + "\n")
    return path


# ---------------------------------------------------------------------------
# schema validation (shared with scripts/trace_report.py)
# ---------------------------------------------------------------------------

def validate_event(ev: Mapping) -> Optional[str]:
    """Schema check for one event dict; returns an error string or None.

    Every event must carry a numeric ``ts``, a ``kind`` from
    :data:`EVENT_KINDS`, and an integer ``step`` and/or ``rid``;
    request-scoped kinds must carry ``rid``."""
    ts = ev.get("ts")
    if not isinstance(ts, (int, float)) or isinstance(ts, bool):
        return f"bad ts: {ts!r}"
    kind = ev.get("kind")
    if kind not in EVENT_KINDS:
        return f"unknown kind: {kind!r}"
    has_rid = isinstance(ev.get("rid"), int) and ev["rid"] >= 0
    has_step = isinstance(ev.get("step"), int) and ev["step"] >= 0
    if not (has_rid or has_step):
        return f"{kind}: neither rid nor step present"
    if kind in _RID_KINDS and not has_rid:
        return f"{kind}: request-scoped kind without rid"
    return None
