"""Paged KV-cache pool: block accounting + block- or slot-resident storage.

Two physical layouts share one ledger:

* **dense** (default) — the model's ``init_cache`` tree with a
  ``max_slots`` batch axis.  Every slot owns its worst-case
  ``max_seq_len`` stripe, so the pool can never actually run dry; the
  block tables are accounting only.
* **paged** (``paged=True``) — attention KV lives in *block* storage:
  every cache leaf's batch axis indexes ``num_blocks + 1`` physical KV
  blocks and its sequence axis is one block (``block_size`` positions)
  wide.  A device-resident ``(max_slots, blocks_per_slot)`` block-table
  tensor maps each slot's token positions to blocks, the decode step
  gathers K/V through it with the Pallas paged-attention kernel, and the
  pool may be sized **smaller than worst case** via the ``num_blocks``
  knob (the ``gpu_memory_utilization`` analogue) — ``OutOfBlocks``
  becomes a real, schedulable event the admission path must survive.
  The extra physical block is the *trash block*: free slots' dummy
  decode rows and unbacked table entries point there, so stray writes
  and speculative DMAs never touch a live sequence's KV.

What this module adds on top of the raw storage is the *paging layer*
a production server needs:

* ``KVBlockPool`` — a fixed budget of KV blocks (``block_size`` token
  positions each) handed out from a free list with ring-buffer semantics:
  blocks freed by a finished sequence go to the tail and are recycled from
  the head, so a retired request's memory is immediately reusable by the
  next admission.  Every block carries a *reference count* so the prefix
  cache can share one block between the radix tree and any number of
  in-flight requests: ``alloc`` hands out a block at refcount 1, ``ref`` /
  ``unref`` move it up and down, and the block returns to the free ring
  only when the count reaches zero.  ``fork`` is the copy-on-write ledger
  op: a fresh block allocated against a live source.  Double-allocation,
  double-free, unref of a dead block, and ``free`` of a shared block are
  hard errors.
* ``PagedKVCache`` — per-slot block tables mapping each live sequence to
  the blocks backing its token positions, grown one block at a time as the
  sequence decodes, plus the scatter that writes a freshly prefilled
  single-sequence cache into its slot of the pooled tree.  When built with
  ``prefix_blocks > 0`` it also owns the *prefix store*: a second
  cache-shaped tree whose batch axis indexes prefix blocks and whose
  sequence axis is one block wide, holding the KV values of cached
  prompt prefixes so a later request can load them instead of recomputing
  the prefill (``save_prefix_block`` / ``load_prefix_block`` /
  ``fork_prefix_block``).

Families without a growing attention cache (pure SSM) still run through
the same ledger: their physical state is constant-size, but the block
table models the logical KV footprint the scheduler admits against, so
occupancy telemetry is comparable across model families.  Such families
(and enc-dec models, whose decoder KV depends on the audio frames, not
the token ids alone) report ``supports_prefix_cache = False`` and skip
the prefix store entirely.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


class OutOfBlocks(RuntimeError):
    """KV pool exhausted — admission must wait for a sequence to finish."""


class KVBlockPool:
    """Fixed-size pool of KV blocks with free-list recycling + refcounts."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = deque(range(num_blocks))
        self._in_use: set = set()
        self._refs: Dict[int, int] = {}
        self.high_water = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocks(
                f"all {self.num_blocks} KV blocks in use")
        b = self._free.popleft()
        assert b not in self._in_use, f"block {b} double-allocated"
        self._in_use.add(b)
        self._refs[b] = 1
        self.high_water = max(self.high_water, len(self._in_use))
        return b

    # -- reference counting (prefix sharing) ---------------------------------

    def refcount(self, b: int) -> int:
        return self._refs.get(b, 0)

    def ref(self, b: int) -> int:
        """Add a reference to a live block (radix node, running request)."""
        assert b in self._in_use, f"block {b} ref'd but not allocated"
        self._refs[b] += 1
        return self._refs[b]

    def unref(self, b: int) -> int:
        """Drop one reference; at zero the block returns to the free ring."""
        assert b in self._in_use, f"block {b} unref'd but not allocated"
        assert self._refs[b] > 0, f"block {b} refcount underflow"
        self._refs[b] -= 1
        left = self._refs[b]
        if left == 0:
            del self._refs[b]
            self._in_use.remove(b)
            self._free.append(b)
        return left

    def fork(self, src: int) -> int:
        """Copy-on-write ledger op: allocate a fresh block that will hold a
        private copy of ``src`` (the caller copies the data).  ``src`` must
        be live — forking a freed block is a hard error."""
        assert src in self._in_use, f"fork of dead block {src}"
        return self.alloc()

    def free(self, blocks: List[int]) -> None:
        """Exclusive-owner release.  Freeing a block somebody else still
        references is a hard error — shared blocks go through ``unref``."""
        for b in blocks:
            assert b in self._in_use, f"block {b} freed but not allocated"
            assert self._refs[b] == 1, \
                f"block {b} freed with refcount {self._refs[b]}"
            del self._refs[b]
            self._in_use.remove(b)
            self._free.append(b)          # ring: recycled oldest-freed first


class PagedKVCache:
    """Pooled decode-cache storage + per-slot block tables.

    ``cache`` is the jitted-decode operand.  Dense mode: the model's
    cache tree with a ``max_slots`` batch axis, ``write_prefill``
    scatters a batch-1 cache (a fresh prefill) into one slot; the
    per-leaf batch-axis index is detected from the model's cache spec,
    so every family (dense, MoE, VLM, SSM, hybrid, enc-dec) works
    unmodified.  Paged mode (``paged=True``): every leaf's batch axis
    indexes ``num_blocks + 1`` KV blocks and its sequence axis is one
    block wide; prefill chunks and decode steps write straight into the
    blocks through the tables (no staging cache),
    ``device_block_tables()`` feeds the Pallas paged-attention gathers,
    and the ``num_blocks`` knob may undersize the pool below
    ``max_slots * blocks_per_slot`` (real ``OutOfBlocks``).

    With ``prefix_blocks > 0`` (and a family whose cache is positional),
    ``prefix_store`` holds block-granular KV snapshots of cached prompt
    prefixes, allocated from ``prefix_pool`` — a second ``KVBlockPool``
    whose refcounts let the radix tree and in-flight requests share them.
    """

    def __init__(self, cfg, max_slots: int, max_seq_len: int,
                 block_size: int = 16, prefix_blocks: int = 0,
                 num_blocks: Optional[int] = None, paged: bool = False):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.paged = paged
        self.blocks_per_slot = -(-max_seq_len // block_size)  # ceil
        worst_case = max_slots * self.blocks_per_slot
        if num_blocks is None:
            num_blocks = worst_case
        if not paged and num_blocks != worst_case:
            raise ValueError(
                "dense layout physically allocates the worst case; the "
                "num_blocks knob needs paged=True")
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.pool = KVBlockPool(num_blocks, block_size)
        # bound by the scheduler (tracing.Tracer); ledger events — block
        # alloc/grow/free and OutOfBlocks — land in the replica's trace
        self.tracer = None
        self._free_slots = deque(range(max_slots))
        self.block_table: Dict[int, List[int]] = {}
        self.seq_len_of: Dict[int, int] = {}
        self._axes = self._batch_axes(cfg, max_seq_len)
        self._seq_axes = self._seq_axis_per_leaf(cfg, max_slots)
        if paged:
            if not self.supports_prefix_cache:
                raise ValueError(
                    f"family {cfg.family!r} has a non-positional decode "
                    "cache; paged attention unsupported")
            if cfg.kv_cache_dtype == "int8":
                raise ValueError(
                    "paged attention does not support the int8 KV cache "
                    "yet — use kv_cache_dtype='bfloat16'")
            if max_seq_len % block_size != 0:
                raise ValueError(
                    f"paged mode needs max_seq_len ({max_seq_len}) to be "
                    f"a multiple of block_size ({block_size})")
            # +1 physical block: the trash block free/dummy rows write to
            self.trash_block = num_blocks
            self.cache = self._init_store(num_blocks + 1)
            self._tables = np.full((max_slots, self.blocks_per_slot),
                                   self.trash_block, np.int32)
            self._tables_dev = None
            self._masked_dev = None       # masked-table upload cache
            self._masked_key = ()
            self._save_paged = None       # built with the prefix store
        else:
            self.cache = T.init_cache(cfg, max_slots, max_seq_len)
            self._write = jax.jit(self._make_write(), donate_argnums=0)

        # -- prefix store (optional) ----------------------------------------
        self.prefix_pool: Optional[KVBlockPool] = None
        self.prefix_store = None
        if prefix_blocks > 0:
            if not self.supports_prefix_cache:
                raise ValueError(
                    f"family {cfg.family!r} has a non-positional decode "
                    "cache; prefix caching unsupported")
            self.prefix_pool = KVBlockPool(prefix_blocks, block_size)
            self.prefix_store = self._init_store(prefix_blocks)
            if paged:
                self._save_paged = jax.jit(self._make_save_paged(),
                                           donate_argnums=0)
            else:
                self._save = jax.jit(self._make_save(), donate_argnums=0)
            self._load = jax.jit(self._make_load(), donate_argnums=0)
            self._copy = jax.jit(self._make_copy(), donate_argnums=0)

    # -- batch-axis detection ------------------------------------------------

    @staticmethod
    def _struct_leaves(cfg, batch, seq_len):
        is_leaf = (lambda x: isinstance(x, tuple) and len(x) == 2
                   and isinstance(x[0], tuple))
        return jax.tree.leaves(T._cache_struct(cfg, batch, seq_len),
                               is_leaf=is_leaf)

    @classmethod
    def _batch_axes(cls, cfg, seq_len: int) -> List[int]:
        """Per-leaf index of the batch axis, found by diffing the cache
        spec at batch=1 vs batch=2 (leaf order matches the cache tree)."""
        s1 = cls._struct_leaves(cfg, 1, seq_len)
        s2 = cls._struct_leaves(cfg, 2, seq_len)
        axes = []
        for (sh1, _), (sh2, _) in zip(s1, s2):
            diff = [i for i, (a, b) in enumerate(zip(sh1, sh2)) if a != b]
            assert len(diff) == 1, (sh1, sh2)
            axes.append(diff[0])
        return axes

    @classmethod
    def _seq_axis_per_leaf(cls, cfg, batch: int) -> List[Optional[int]]:
        """Per-leaf index of the token-position axis, found by diffing the
        cache spec at two sequence lengths.  ``None`` for leaves with no
        positional extent (SSM state / conv tails) — those families cannot
        be prefix-cached positionally."""
        s1 = cls._struct_leaves(cfg, batch, 8)
        s2 = cls._struct_leaves(cfg, batch, 16)
        axes: List[Optional[int]] = []
        for (sh1, _), (sh2, _) in zip(s1, s2):
            diff = [i for i, (a, b) in enumerate(zip(sh1, sh2)) if a != b]
            assert len(diff) <= 1, (sh1, sh2)
            axes.append(diff[0] if diff else None)
        return axes

    @property
    def supports_prefix_cache(self) -> bool:
        """True when every cache leaf is positional (sliceable per token)
        and the KV depends on the token ids alone — enc-dec decoder KV
        also depends on the encoder frames, so token-keyed reuse is
        unsound there."""
        return (self.cfg.family != "encdec"
                and all(ax is not None for ax in self._seq_axes))

    # -- scatter / gather programs -------------------------------------------

    def _make_write(self):
        axes = self._axes

        def write(pooled, single, slot):
            leaves_p, treedef = jax.tree.flatten(pooled)
            leaves_s = jax.tree.leaves(single)
            out = []
            for lp, ls, ax in zip(leaves_p, leaves_s, axes):
                lead = (slice(None),) * ax
                out.append(lp.at[lead + (slot,)].set(ls[lead + (0,)]))
            return jax.tree.unflatten(treedef, out)

        return write

    def _init_store(self, prefix_blocks: int):
        """Cache-shaped tree: batch axis -> prefix blocks, seq axis -> one
        block of token positions.  Dtypes match the live cache exactly, so
        a save/load roundtrip is bit-identical (int8 KV included)."""
        leaves = self._struct_leaves(self.cfg, 1, self.max_seq_len)
        treedef = jax.tree.structure(
            T.init_cache_specs(self.cfg, 1, self.max_seq_len))
        out = []
        for (shape, dtype), bax, sax in zip(leaves, self._axes,
                                            self._seq_axes):
            sh = list(shape)
            sh[bax] = prefix_blocks
            sh[sax] = self.block_size
            out.append(jnp.zeros(tuple(sh), dtype))
        return jax.tree.unflatten(treedef, out)

    def _make_save(self):
        """store <- pooled[slot, pos0:pos0+bs] at block ``bid``."""
        baxes, saxes, bs = self._axes, self._seq_axes, self.block_size

        def save(store, pooled, slot, bid, pos0):
            leaves_st, treedef = jax.tree.flatten(store)
            leaves_p = jax.tree.leaves(pooled)
            out = []
            for lst, lp, bax, sax in zip(leaves_st, leaves_p, baxes, saxes):
                piece = jax.lax.dynamic_index_in_dim(lp, slot, axis=bax,
                                                     keepdims=True)
                piece = jax.lax.dynamic_slice_in_dim(piece, pos0, bs,
                                                     axis=sax)
                starts = [jnp.int32(0)] * lst.ndim
                starts[bax] = bid
                out.append(jax.lax.dynamic_update_slice(lst, piece, starts))
            return jax.tree.unflatten(treedef, out)

        return save

    def _make_load(self):
        """dest(batch-1 cache)[0, bidx*bs : +bs] <- store[bid]."""
        baxes, saxes, bs = self._axes, self._seq_axes, self.block_size

        def load(dest, store, bid, bidx):
            leaves_d, treedef = jax.tree.flatten(dest)
            leaves_st = jax.tree.leaves(store)
            out = []
            for ld, lst, bax, sax in zip(leaves_d, leaves_st, baxes, saxes):
                piece = jax.lax.dynamic_index_in_dim(lst, bid, axis=bax,
                                                     keepdims=True)
                starts = [jnp.int32(0)] * ld.ndim
                starts[sax] = bidx * bs
                out.append(jax.lax.dynamic_update_slice(ld, piece, starts))
            return jax.tree.unflatten(treedef, out)

        return load

    def _make_copy(self):
        """store[dst] <- store[src] (the physical half of copy-on-write)."""
        baxes = self._axes

        def copy(store, src, dst):
            leaves, treedef = jax.tree.flatten(store)
            out = []
            for lst, bax in zip(leaves, baxes):
                piece = jax.lax.dynamic_index_in_dim(lst, src, axis=bax,
                                                     keepdims=True)
                starts = [jnp.int32(0)] * lst.ndim
                starts[bax] = dst
                out.append(jax.lax.dynamic_update_slice(lst, piece, starts))
            return jax.tree.unflatten(treedef, out)

        return copy

    def _make_save_paged(self):
        """prefix_store[dst] <- block_storage[src] — in paged mode a
        prefix snapshot is a straight block-to-block copy (both trees
        share the (blocks, block_size) leaf layout)."""
        baxes = self._axes

        def save(store, storage, src, dst):
            leaves_st, treedef = jax.tree.flatten(store)
            leaves_bs = jax.tree.leaves(storage)
            out = []
            for lst, lbs, bax in zip(leaves_st, leaves_bs, baxes):
                piece = jax.lax.dynamic_index_in_dim(lbs, src, axis=bax,
                                                     keepdims=True)
                starts = [jnp.int32(0)] * lst.ndim
                starts[bax] = dst
                out.append(jax.lax.dynamic_update_slice(lst, piece, starts))
            return jax.tree.unflatten(treedef, out)

        return save

    # -- prefix-store operations ---------------------------------------------

    def save_prefix_block(self, slot: int, pos0: int,
                          into: Optional[int] = None) -> int:
        """Snapshot pooled-cache positions ``[pos0, pos0+block_size)`` of
        ``slot`` into a prefix block (freshly allocated unless ``into`` is
        given).  Returns the block id."""
        assert self.prefix_pool is not None, "prefix store not enabled"
        assert pos0 + self.block_size <= self.max_seq_len, \
            f"prefix block [{pos0}, {pos0 + self.block_size}) overruns cache"
        # ownership transfers to the PrefixCache radix tree: its node
        # release/_remove paths unref this block, not this class
        bid = self.prefix_pool.alloc() if into is None else into  # repro-lint: disable=RL005
        if self.paged:
            # aligned window == exactly one pool block of this slot
            assert pos0 % self.block_size == 0, pos0
            src = self.block_table[slot][pos0 // self.block_size]
            self.prefix_store = self._save_paged(
                self.prefix_store, self.cache, jnp.int32(src),
                jnp.int32(bid))
        else:
            self.prefix_store = self._save(
                self.prefix_store, self.cache, jnp.int32(slot),
                jnp.int32(bid), jnp.int32(pos0))
        return bid

    def load_prefix_blocks(self, cache1, blocks: Sequence[int]):
        """Copy stored prefix blocks into a batch-1 cache at their aligned
        positions (block k of the list covers ``[k*bs, (k+1)*bs)``).
        Returns the updated cache tree."""
        assert self.prefix_pool is not None, "prefix store not enabled"
        for k, bid in enumerate(blocks):
            cache1 = self._load(cache1, self.prefix_store, jnp.int32(bid),
                                jnp.int32(k))
        return cache1

    def load_prefix_blocks_paged(self, slot: int,
                                 blocks: Sequence[int]) -> None:
        """Paged resume path: copy stored prefix blocks straight into
        ``slot``'s pool blocks (block k of the list covers positions
        ``[k*bs, (k+1)*bs)``), with no batch-1 staging cache in between.
        The slot must already back those positions (``alloc_slot`` with
        the full prompt length does)."""
        assert self.paged, "block-to-block prefix load needs paged mode"
        assert self.prefix_pool is not None, "prefix store not enabled"
        table = self.block_table[slot]
        assert len(blocks) <= len(table), (len(blocks), len(table))
        for k, bid in enumerate(blocks):
            # same block-to-block copy program as the save direction,
            # with the trees swapped: cache[table[k]] <- prefix_store[bid]
            self.cache = self._save_paged(
                self.cache, self.prefix_store, jnp.int32(bid),
                jnp.int32(table[k]))

    def table_row(self, slot: int) -> np.ndarray:
        """The slot's padded block-table row (unbacked entries name the
        trash block) — what a batched-prefill program row gathers through."""
        assert self.paged, "block tables are device-resident in paged mode"
        return self._tables[slot]

    def fork_prefix_block(self, src: int) -> int:
        """Copy-on-write: a private copy of a shared prefix block, so a
        diverging branch never mutates data another reader still maps."""
        assert self.prefix_pool is not None, "prefix store not enabled"
        # ownership transfers to the PrefixCache branch that requested
        # the fork; its release/_remove paths unref the copy
        dst = self.prefix_pool.fork(src)  # repro-lint: disable=RL005
        self.prefix_store = self._copy(self.prefix_store, jnp.int32(src),
                                       jnp.int32(dst))
        return dst

    # -- slot lifecycle ------------------------------------------------------

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def _blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    def alloc_slot(self, prompt_len: int) -> int:
        """Claim a slot and the blocks backing its prompt positions.
        On block exhaustion the slot is returned and any partially
        allocated blocks are released before ``OutOfBlocks`` propagates —
        the caller sees an all-or-nothing admission."""
        if prompt_len > self.max_seq_len:
            raise ValueError(
                f"prompt ({prompt_len}) exceeds max_seq_len "
                f"({self.max_seq_len})")
        tr = self.tracer
        if not self._free_slots:
            if tr is not None and tr.enabled:
                tr.out_of_blocks("alloc_slot:no_free_slot")
            raise OutOfBlocks("no free slot")
        slot = self._free_slots.popleft()
        blocks: List[int] = []
        try:
            for _ in range(self._blocks_for(prompt_len)):
                blocks.append(self.pool.alloc())
        except OutOfBlocks:
            self.pool.free(blocks)
            self._free_slots.appendleft(slot)
            if tr is not None and tr.enabled:
                tr.out_of_blocks("alloc_slot:pool_dry", slot)
            raise
        self.block_table[slot] = blocks
        self.seq_len_of[slot] = prompt_len
        if self.paged:
            self._tables[slot, :len(blocks)] = blocks
            self._tables_dev = self._masked_dev = None
        if tr is not None and tr.enabled:
            tr.block_alloc(slot, len(blocks), self.pool.available)
        return slot

    def ensure_capacity(self, slot: int, n_tokens: int) -> None:
        """Back token positions [0, n_tokens) with blocks, growing the
        slot's table from the shared pool as decode advances."""
        tr = self.tracer
        if n_tokens > self.max_seq_len:
            raise OutOfBlocks(
                f"slot {slot}: {n_tokens} tokens exceeds max_seq_len "
                f"({self.max_seq_len})")
        table = self.block_table[slot]
        while len(table) * self.block_size < n_tokens:
            try:
                table.append(self.pool.alloc())
            except OutOfBlocks:
                if tr is not None and tr.enabled:
                    tr.out_of_blocks("decode_grow", slot)
                raise
            if self.paged:
                self._tables[slot, len(table) - 1] = table[-1]
                self._tables_dev = self._masked_dev = None
            if tr is not None and tr.enabled:
                tr.block_grow(slot, self.pool.available)
        self.seq_len_of[slot] = max(self.seq_len_of[slot], n_tokens)

    def free_slot(self, slot: int) -> None:
        """Retire a sequence: its blocks go straight back on the ring."""
        blocks = self.block_table.pop(slot)
        self.pool.free(blocks)
        del self.seq_len_of[slot]
        self._free_slots.append(slot)
        if self.paged:
            self._tables[slot, :] = self.trash_block
            self._tables_dev = self._masked_dev = None
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.block_free(slot, len(blocks), self.pool.available)

    def device_block_tables(self, mask_slots: Sequence[int] = ()
                            ) -> jnp.ndarray:
        """The (max_slots, blocks_per_slot) int32 block-table tensor the
        paged decode step gathers through; uploaded lazily after ledger
        mutations.  Unbacked entries name the trash block.

        ``mask_slots`` re-routes those slots' rows to the trash block —
        the decode step passes the mid-prefill slots here so their
        dummy decode rows can never touch KV the prefill already wrote.
        The masked upload is cached too (keyed by the mask), so steady
        interleaved decode pays one host-to-device transfer per ledger
        or mask change, not one per step."""
        assert self.paged, "block tables are device-resident in paged mode"
        key = tuple(sorted(mask_slots))
        if not key:
            if self._tables_dev is None:
                self._tables_dev = jnp.asarray(self._tables)
            return self._tables_dev
        if self._masked_dev is None or self._masked_key != key:
            masked = self._tables.copy()
            masked[list(key), :] = self.trash_block
            self._masked_dev = jnp.asarray(masked)
            self._masked_key = key
        return self._masked_dev

    def write_prefill(self, slot: int, single_cache) -> None:
        """Scatter a batch-1 prefilled cache into ``slot``'s stripe of
        the dense storage.  Paged prefill never stages a batch-1 cache —
        chunks land straight in pool blocks (see ``T.prefill_step``)."""
        assert not self.paged, (
            "paged prefill writes chunks straight into pool blocks; "
            "there is no batch-1 cache to scatter")
        self.cache = self._write(self.cache, single_cache,
                                 jnp.asarray(slot, jnp.int32))

    # -- telemetry -----------------------------------------------------------

    def kv_bytes(self) -> int:
        """Physical bytes resident for the decode KV storage (the number
        the paged/dense benchmark holds fixed while varying concurrency)."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def occupancy(self) -> Dict[str, float]:
        occ = {
            "slots_in_use": self.max_slots - len(self._free_slots),
            "max_slots": self.max_slots,
            "blocks_in_use": self.pool.in_use,
            "blocks_total": self.pool.num_blocks,
            "block_high_water": self.pool.high_water,
            "block_utilization": self.pool.in_use / self.pool.num_blocks,
            "paged": self.paged,
            "kv_bytes_resident": self.kv_bytes(),
        }
        if self.prefix_pool is not None:
            occ["prefix_blocks_in_use"] = self.prefix_pool.in_use
            occ["prefix_blocks_total"] = self.prefix_pool.num_blocks
        return occ
