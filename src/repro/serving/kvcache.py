"""Paged KV-cache pool: block accounting + slot-resident cache storage.

The physical decode cache stays in the model's dense layout — one
``init_cache`` tree with a ``max_slots`` batch axis, because ``decode_step``
is jitted over fixed shapes.  What this module adds is the *paging layer*
a production server needs on top of that storage:

* ``KVBlockPool`` — a fixed budget of KV blocks (``block_size`` token
  positions each) handed out from a free list with ring-buffer semantics:
  blocks freed by a finished sequence go to the tail and are recycled from
  the head, so a retired request's memory is immediately reusable by the
  next admission.  Double-allocation and double-free are hard errors.
* ``PagedKVCache`` — per-slot block tables mapping each live sequence to
  the blocks backing its token positions, grown one block at a time as the
  sequence decodes, plus the scatter that writes a freshly prefilled
  single-sequence cache into its slot of the pooled tree.

Families without a growing attention cache (pure SSM) still run through
the same ledger: their physical state is constant-size, but the block
table models the logical KV footprint the scheduler admits against, so
occupancy telemetry is comparable across model families.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.models import transformer as T


class OutOfBlocks(RuntimeError):
    """KV pool exhausted — admission must wait for a sequence to finish."""


class KVBlockPool:
    """Fixed-size pool of KV blocks with free-list recycling."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = deque(range(num_blocks))
        self._in_use: set = set()
        self.high_water = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocks(
                f"all {self.num_blocks} KV blocks in use")
        b = self._free.popleft()
        assert b not in self._in_use, f"block {b} double-allocated"
        self._in_use.add(b)
        self.high_water = max(self.high_water, len(self._in_use))
        return b

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            assert b in self._in_use, f"block {b} freed but not allocated"
            self._in_use.remove(b)
            self._free.append(b)          # ring: recycled oldest-freed first


class PagedKVCache:
    """Slot-resident pooled cache + per-slot block tables.

    ``cache`` is the jitted-decode operand: the model's cache tree with a
    ``max_slots`` batch axis.  ``write_prefill`` scatters a batch-1 cache
    (a fresh prefill) into one slot; the per-leaf batch-axis index is
    detected from the model's cache spec, so every family (dense, MoE,
    VLM, SSM, hybrid, enc-dec) works unmodified.
    """

    def __init__(self, cfg, max_slots: int, max_seq_len: int,
                 block_size: int = 16):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        blocks_per_slot = -(-max_seq_len // block_size)       # ceil
        self.pool = KVBlockPool(max_slots * blocks_per_slot, block_size)
        self.cache = T.init_cache(cfg, max_slots, max_seq_len)
        self._free_slots = deque(range(max_slots))
        self.block_table: Dict[int, List[int]] = {}
        self.seq_len_of: Dict[int, int] = {}
        self._axes = self._batch_axes(cfg, max_seq_len)
        self._write = jax.jit(self._make_write(), donate_argnums=0)

    # -- batch-axis detection ------------------------------------------------

    @staticmethod
    def _batch_axes(cfg, seq_len: int) -> List[int]:
        """Per-leaf index of the batch axis, found by diffing the cache
        spec at batch=1 vs batch=2 (leaf order matches the cache tree)."""
        is_leaf = (lambda x: isinstance(x, tuple) and len(x) == 2
                   and isinstance(x[0], tuple))
        s1 = jax.tree.leaves(T._cache_struct(cfg, 1, seq_len), is_leaf=is_leaf)
        s2 = jax.tree.leaves(T._cache_struct(cfg, 2, seq_len), is_leaf=is_leaf)
        axes = []
        for (sh1, _), (sh2, _) in zip(s1, s2):
            diff = [i for i, (a, b) in enumerate(zip(sh1, sh2)) if a != b]
            assert len(diff) == 1, (sh1, sh2)
            axes.append(diff[0])
        return axes

    def _make_write(self):
        axes = self._axes

        def write(pooled, single, slot):
            leaves_p, treedef = jax.tree.flatten(pooled)
            leaves_s = jax.tree.leaves(single)
            out = []
            for lp, ls, ax in zip(leaves_p, leaves_s, axes):
                lead = (slice(None),) * ax
                out.append(lp.at[lead + (slot,)].set(ls[lead + (0,)]))
            return jax.tree.unflatten(treedef, out)

        return write

    # -- slot lifecycle ------------------------------------------------------

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def _blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    def alloc_slot(self, prompt_len: int) -> int:
        """Claim a slot and the blocks backing its prompt positions."""
        if prompt_len > self.max_seq_len:
            raise ValueError(
                f"prompt ({prompt_len}) exceeds max_seq_len "
                f"({self.max_seq_len})")
        if not self._free_slots:
            raise OutOfBlocks("no free slot")
        slot = self._free_slots.popleft()
        try:
            blocks = [self.pool.alloc()
                      for _ in range(self._blocks_for(prompt_len))]
        except OutOfBlocks:
            self._free_slots.appendleft(slot)
            raise
        self.block_table[slot] = blocks
        self.seq_len_of[slot] = prompt_len
        return slot

    def ensure_capacity(self, slot: int, n_tokens: int) -> None:
        """Back token positions [0, n_tokens) with blocks, growing the
        slot's table from the shared pool as decode advances."""
        if n_tokens > self.max_seq_len:
            raise OutOfBlocks(
                f"slot {slot}: {n_tokens} tokens exceeds max_seq_len "
                f"({self.max_seq_len})")
        table = self.block_table[slot]
        while len(table) * self.block_size < n_tokens:
            table.append(self.pool.alloc())
        self.seq_len_of[slot] = max(self.seq_len_of[slot], n_tokens)

    def free_slot(self, slot: int) -> None:
        """Retire a sequence: its blocks go straight back on the ring."""
        self.pool.free(self.block_table.pop(slot))
        del self.seq_len_of[slot]
        self._free_slots.append(slot)

    def write_prefill(self, slot: int, single_cache) -> None:
        """Scatter a batch-1 prefilled cache into ``slot`` of the pool."""
        self.cache = self._write(self.cache, single_cache,
                                 jnp.asarray(slot, jnp.int32))

    # -- telemetry -----------------------------------------------------------

    def occupancy(self) -> Dict[str, float]:
        return {
            "slots_in_use": self.max_slots - len(self._free_slots),
            "max_slots": self.max_slots,
            "blocks_in_use": self.pool.in_use,
            "blocks_total": self.pool.num_blocks,
            "block_high_water": self.pool.high_water,
            "block_utilization": self.pool.in_use / self.pool.num_blocks,
        }
