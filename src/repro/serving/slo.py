"""Per-tenant SLO monitoring for long-lived capsule deployments.

The source paper's deployments are *services*: a container image lands on
a secure HPC system and serves mixed traffic for months.  ROADMAP item 5
calls for per-tenant TTFT/jitter percentiles "so mixed-SLA traffic is
measurable, not just served" — this module is that measurement layer,
built entirely inside the capsule (no external monitoring stack; breach
events land in the same file-based trace exports as everything else).

Pieces, bottom-up:

* :class:`SlidingWindow` — a bounded percentile estimator.  Percentiles
  are exact over the most recent ``window`` samples (a ring — month-long
  deployments must not grow memory without bound); ``count`` / ``mean`` /
  ``max`` are running scalars over *all* samples ever added, so totals
  stay exact even after the ring wraps.  Below ``window`` samples the
  ring holds everything and percentiles are exact over the full history
  (the "exact-mode fallback").

* :class:`TenantStats` — one tenant's windows (TTFT, inter-token gap,
  queue wait, all in ms) plus running request/token counters and a
  tokens/s over the tenant's own submit→finish span.

* :class:`SLOPolicy` / :class:`SLOConfig` — declarative thresholds.  A
  config is a default policy plus per-tenant overrides, loadable from
  JSON (``launch/serve.py --slo-config``)::

      {"default": {"ttft_p95_ms": 500, "gap_p95_ms": 50},
       "tenants": {"premium": {"ttft_p95_ms": 200, "min_samples": 4}}}

* :class:`SLOMonitor` — evaluates policies against per-tenant stats and
  reports *state transitions* (enter-breach / recover), which the
  :class:`~repro.serving.tracing.Tracer` emits as ``slo_breach`` events.
  Edge-triggered on purpose: a sustained breach is one event plus one
  recovery, not one event per scheduler step.

This module sits below :mod:`repro.serving.metrics` in the import graph
(metrics holds the per-tenant :class:`TenantStats` and merges their
summaries) and must not import it.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, fields
from pathlib import Path
from typing import Dict, List, Mapping, Optional


def _pct_of(xs, q: float) -> float:
    """Linear-interpolation percentile (numpy-free, same formula as
    ``metrics._pct`` — duplicated to keep this module import-root)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    f = (len(s) - 1) * q
    lo, hi = int(f), min(int(f) + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (f - lo)


DEFAULT_WINDOW = 512


class SlidingWindow:
    """Bounded percentile estimator: exact percentiles over the last
    ``window`` samples, exact running count/sum/max over all samples."""

    __slots__ = ("ring", "count", "total", "peak")

    def __init__(self, window: int = DEFAULT_WINDOW):
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        self.ring: deque = deque(maxlen=window)
        self.count = 0          # all-time
        self.total = 0.0        # all-time
        self.peak = 0.0         # all-time

    @property
    def window(self) -> int:
        return self.ring.maxlen

    def add(self, x: float) -> None:
        self.ring.append(x)
        self.count += 1
        self.total += x
        if x > self.peak:
            self.peak = x

    def percentile(self, q: float) -> float:
        return _pct_of(self.ring, q)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        """``p50``/``p95`` over the window; ``max``/``mean``/``count``
        all-time (documented asymmetry: percentiles answer "how is it
        *now*", the scalars answer "what happened overall")."""
        return {"p50": self.percentile(0.5), "p95": self.percentile(0.95),
                "max": self.peak, "mean": self.mean, "count": self.count}


def merge_window_summaries(summaries: List[Mapping]) -> Dict[str, float]:
    """Cross-replica merge of :meth:`SlidingWindow.summary` dicts: counts
    sum, percentiles take the conservative bound (max), the mean is
    count-weighted.  Windows with ``count == 0`` contribute nothing — an
    idle replica must not dilate or dilute a tenant's percentiles (the
    PR 5 zero-decode-replica regression, extended to tenants)."""
    live = [s for s in summaries if s.get("count", 0) > 0]
    n = sum(int(s["count"]) for s in live)
    return {
        "p50": max((float(s.get("p50", 0.0)) for s in live), default=0.0),
        "p95": max((float(s.get("p95", 0.0)) for s in live), default=0.0),
        "max": max((float(s.get("max", 0.0)) for s in live), default=0.0),
        "mean": (sum(float(s.get("mean", 0.0)) * int(s["count"])
                     for s in live) / n if n else 0.0),
        "count": n,
    }


class TenantStats:
    """One tenant's serving telemetry: bounded windows + running totals."""

    def __init__(self, window: int = DEFAULT_WINDOW):
        self.ttft_ms = SlidingWindow(window)
        self.gap_ms = SlidingWindow(window)        # inter-token, per rid
        self.queue_wait_ms = SlidingWindow(window)
        self.submitted = 0
        self.completed = 0
        self.new_tokens = 0
        self.first_submit_ts: Optional[float] = None
        self.last_finish_ts: Optional[float] = None

    def tokens_per_s(self) -> float:
        if self.first_submit_ts is None or self.last_finish_ts is None:
            return 0.0
        span = self.last_finish_ts - self.first_submit_ts
        return self.new_tokens / span if span > 0 else 0.0

    def summary(self) -> Dict[str, object]:
        return {
            "requests_submitted": self.submitted,
            "requests_completed": self.completed,
            "new_tokens": self.new_tokens,
            "tokens_per_s": self.tokens_per_s(),
            "ttft_ms": self.ttft_ms.summary(),
            "decode_gap_ms": self.gap_ms.summary(),
            "queue_wait_ms": self.queue_wait_ms.summary(),
        }


def merge_tenant_summaries(per_tenant: List[Mapping[str, Mapping]]
                           ) -> Dict[str, Dict[str, object]]:
    """Merge ``{tenant: TenantStats.summary()}`` maps across replicas.
    Tenants union (disjoint keys pass through unchanged); overlapping
    keys merge window-wise via :func:`merge_window_summaries`."""
    names: List[str] = []
    for m in per_tenant:
        for name in m:
            if name not in names:
                names.append(name)
    merged: Dict[str, Dict[str, object]] = {}
    for name in sorted(names):
        ss = [m[name] for m in per_tenant if name in m]
        merged[name] = {
            "requests_submitted": sum(int(s.get("requests_submitted", 0))
                                      for s in ss),
            "requests_completed": sum(int(s.get("requests_completed", 0))
                                      for s in ss),
            "new_tokens": sum(int(s.get("new_tokens", 0)) for s in ss),
            "tokens_per_s": sum(float(s.get("tokens_per_s", 0.0))
                                for s in ss),
            "ttft_ms": merge_window_summaries(
                [s.get("ttft_ms", {}) for s in ss]),
            "decode_gap_ms": merge_window_summaries(
                [s.get("decode_gap_ms", {}) for s in ss]),
            "queue_wait_ms": merge_window_summaries(
                [s.get("queue_wait_ms", {}) for s in ss]),
        }
    return merged


# ---------------------------------------------------------------------------
# declarative policies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SLOPolicy:
    """Thresholds for one tenant.  ``None`` disables that check.  Upper
    bounds are on windowed p95s (ms); ``min_tokens_per_s`` is a lower
    bound on the tenant's running throughput.  ``min_samples`` gates
    every windowed check — no verdicts on thin data."""
    ttft_p95_ms: Optional[float] = None
    gap_p95_ms: Optional[float] = None
    queue_wait_p95_ms: Optional[float] = None
    min_tokens_per_s: Optional[float] = None
    min_samples: int = 8

    @classmethod
    def from_dict(cls, d: Mapping) -> "SLOPolicy":
        known = {f.name for f in fields(cls)}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown SLO policy keys: {sorted(bad)} "
                             f"(known: {sorted(known)})")
        return cls(**d)

    def to_dict(self) -> Dict[str, object]:
        return {f.name: getattr(self, f.name) for f in fields(self)
                if getattr(self, f.name) is not None or f.name == "min_samples"}


class SLOConfig:
    """A default policy plus per-tenant overrides."""

    def __init__(self, default: Optional[SLOPolicy] = None,
                 tenants: Optional[Mapping[str, SLOPolicy]] = None):
        self.default = default or SLOPolicy()
        self.tenants: Dict[str, SLOPolicy] = dict(tenants or {})

    def policy_for(self, tenant: str) -> SLOPolicy:
        return self.tenants.get(tenant, self.default)

    @classmethod
    def from_dict(cls, d: Mapping) -> "SLOConfig":
        default = SLOPolicy.from_dict(d.get("default", {}))
        tenants = {name: SLOPolicy.from_dict(pol)
                   for name, pol in d.get("tenants", {}).items()}
        return cls(default, tenants)

    @classmethod
    def from_json(cls, path) -> "SLOConfig":
        return cls.from_dict(json.loads(Path(path).read_text()))

    def to_dict(self) -> Dict[str, object]:
        return {"default": self.default.to_dict(),
                "tenants": {n: p.to_dict()
                            for n, p in sorted(self.tenants.items())}}


class SLOMonitor:
    """Edge-triggered policy evaluation over per-tenant stats.

    :meth:`evaluate` compares each tenant's windowed stats against its
    policy and returns the *transitions* since the previous call — a
    check newly entering breach, or a breached check recovering.  The
    tracer turns each transition into one ``slo_breach`` event (with a
    ``recovered`` flag), so the event log records breach spans, not a
    per-step alarm flood.  Breach totals accumulate here regardless of
    whether a tracer is attached (disabled tracing still counts).
    """

    def __init__(self, config: Optional[SLOConfig] = None):
        self.config = config or SLOConfig()
        self._state: Dict[tuple, bool] = {}   # (tenant, metric) -> breached
        self.breaches = 0                     # enter-breach transitions

    def _checks(self, tenant: str, stats: TenantStats):
        pol = self.config.policy_for(tenant)
        out = []
        for metric, win, bound in (
                ("ttft_p95_ms", stats.ttft_ms, pol.ttft_p95_ms),
                ("gap_p95_ms", stats.gap_ms, pol.gap_p95_ms),
                ("queue_wait_p95_ms", stats.queue_wait_ms,
                 pol.queue_wait_p95_ms)):
            if bound is None or win.count < pol.min_samples:
                continue
            out.append((metric, win.percentile(0.95), bound,
                        win.percentile(0.95) > bound))
        if (pol.min_tokens_per_s is not None
                and stats.completed >= pol.min_samples):
            tps = stats.tokens_per_s()
            out.append(("min_tokens_per_s", tps, pol.min_tokens_per_s,
                        tps < pol.min_tokens_per_s))
        return out

    def evaluate(self, tenants: Mapping[str, TenantStats]) -> List[dict]:
        transitions: List[dict] = []
        for tenant in sorted(tenants):
            for metric, observed, threshold, breached in self._checks(
                    tenant, tenants[tenant]):
                key = (tenant, metric)
                if breached == self._state.get(key, False):
                    continue
                self._state[key] = breached
                if breached:
                    self.breaches += 1
                transitions.append({
                    "tenant": tenant, "metric": metric,
                    "observed": observed, "threshold": threshold,
                    "recovered": not breached,
                })
        return transitions

    def active_breaches(self) -> List[Dict[str, str]]:
        return [{"tenant": t, "metric": m}
                for (t, m), breached in sorted(self._state.items())
                if breached]

    def summary(self) -> Dict[str, object]:
        return {"breaches": self.breaches,
                "active": self.active_breaches(),
                "tenant_policies": len(self.config.tenants)}
