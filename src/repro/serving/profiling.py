"""In-capsule performance profiling for the serving stack.

The capsule cannot run an external profiler daemon, so the three things
an operator needs to localize a slowdown are built in:

* :class:`StepProfiler` — device-accurate step-phase timing.  The
  scheduler's phase timestamps normally measure *dispatch* (JAX is
  async); with profiling on, the scheduler brackets each phase with
  ``block_until_ready`` so the deltas are wall time the device actually
  spent in admit / prefill / decode / sample.  Windows are bounded
  (:class:`~repro.serving.slo.SlidingWindow`).

* :func:`profile_kernel` / :func:`profile_paged_kernels` — per-kernel
  profiles for the paged attention kernels at serving shapes: compiled
  ``cost_analysis()`` FLOPs/bytes plus measured wall time, reduced to
  achieved fractions of the roofline peaks (``benchmarks/roofline.py``'s
  constants when importable; the same v5p numbers inlined as a fallback
  because ``benchmarks/`` is not a package on the capsule's path).  On
  CPU the kernels run in interpret mode, so the achieved fractions are
  meaningful only on real hardware — the *structure* (flops > 0, bytes >
  0, wall > 0) is what tests pin.

* :class:`RecompilationTracker` — jit recompilation telemetry.  XLA's
  jit cache keys on argument shapes/dtypes; a serving loop that lets a
  batch dimension wobble (e.g. sizing the decode batch to the number of
  *live* slots instead of padding to ``max_slots``) silently recompiles
  every few steps — the classic variable-batch serving bug.  The engine
  reports each jitted program's argument signature here; a signature
  never seen before counts as a compilation, and any compilation after
  :meth:`~RecompilationTracker.mark_warm` (or beyond the first signature
  per program) emits a ``recompile`` warning event through the tracer.
  Steady-state serving must report **zero** post-warm recompiles — the
  benchmark asserts it.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.slo import SlidingWindow

try:                                    # repo-root runs (benchmarks/ CI)
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS
except Exception:                       # in-capsule: same v5p peaks
    PEAK_FLOPS = 197e12
    HBM_BW = 819e9

PHASES = ("admit", "prefill", "decode", "sample")


class StepProfiler:
    """Bounded per-phase timing windows, fed by ``Scheduler.step()``
    when the scheduler is constructed with ``profile=True``."""

    def __init__(self, window: int = 512):
        self.phases: Dict[str, SlidingWindow] = {
            p: SlidingWindow(window) for p in PHASES}
        self.steps = 0

    def record_step(self, admit_s: float, prefill_s: float,
                    decode_s: float, sample_s: float) -> None:
        self.steps += 1
        for name, dur in zip(PHASES, (admit_s, prefill_s,
                                      decode_s, sample_s)):
            self.phases[name].add(dur * 1e3)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {"steps": self.steps}
        for name, win in self.phases.items():
            out[f"{name}_ms"] = win.summary()
        return out


def _cost_dict(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on current jax but a
    one-element list of dicts on older releases — normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def profile_kernel(fn: Callable, *args, name: str, reps: int = 5,
                   clock=time.perf_counter, **kwargs) -> Dict[str, object]:
    """Profile one jitted program at the given arguments.

    Lowers+compiles once for ``cost_analysis()`` (FLOPs / bytes
    accessed), then times ``reps`` executions bracketed by
    ``block_until_ready`` and reports the median wall plus achieved
    fractions of the roofline compute and bandwidth peaks."""
    import jax

    compiled = jax.jit(fn).lower(*args, **kwargs).compile() \
        if not hasattr(fn, "lower") else fn.lower(*args, **kwargs).compile()
    cost = _cost_dict(compiled.cost_analysis())
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    jax.block_until_ready(fn(*args, **kwargs))      # warm the jit cache
    walls: List[float] = []
    for _ in range(max(reps, 1)):
        t0 = clock()
        jax.block_until_ready(fn(*args, **kwargs))
        walls.append(clock() - t0)
    walls.sort()
    wall = walls[len(walls) // 2]
    achieved_flops = flops / wall if wall > 0 else 0.0
    achieved_bw = bytes_accessed / wall if wall > 0 else 0.0
    return {
        "name": name,
        "reps": len(walls),
        "wall_ms_median": wall * 1e3,
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "achieved_tflops": achieved_flops / 1e12,
        "achieved_gbps": achieved_bw / 1e9,
        "fraction_of_peak_flops": achieved_flops / PEAK_FLOPS,
        "fraction_of_peak_bw": achieved_bw / HBM_BW,
        "arithmetic_intensity": (flops / bytes_accessed
                                 if bytes_accessed > 0 else 0.0),
    }


def profile_paged_kernels(engine, reps: int = 3,
                          chunk: int = 8) -> Dict[str, Dict[str, object]]:
    """Profile ``paged_decode_attention`` and ``paged_prefill_attention``
    at the engine's own serving shapes (its batch width, page geometry
    and head layout), on synthetic operands.  Requires a paged engine."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    if not getattr(engine, "paged", False):
        raise ValueError("kernel profiling requires a paged engine")
    cfg, kv = engine.cfg, engine.kv
    B = engine.max_slots
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pages, page = kv.pool.num_blocks + 1, kv.block_size   # + trash block
    rng = np.random.default_rng(0)
    q1 = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pages, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pages, page, KV, D)), jnp.float32)
    tables = jnp.asarray(
        np.arange(B * kv.blocks_per_slot, dtype=np.int32).reshape(
            B, kv.blocks_per_slot) % kv.pool.num_blocks)
    lengths = jnp.full((B,), min(page * kv.blocks_per_slot,
                                 engine.max_seq_len), jnp.int32)
    C = min(chunk, engine.max_seq_len)
    qc = jnp.asarray(rng.standard_normal((B, C, H, D)), jnp.float32)
    starts = jnp.zeros((B,), jnp.int32)
    qlens = jnp.full((B,), C, jnp.int32)
    return {
        "paged_attention": profile_kernel(
            ops.paged_decode_attention, q1, kp, vp, tables, lengths,
            name="paged_attention", reps=reps),
        "paged_prefill": profile_kernel(
            ops.paged_prefill_attention, qc, kp, vp, tables, starts, qlens,
            name="paged_prefill", reps=reps),
    }


class RecompilationTracker:
    """Shape-signature compilation counter for the engine's jitted
    programs.  ``observe`` is on the hot path — one tuple hash and one
    set lookup per call — and only does real work on a novel signature."""

    def __init__(self):
        self.signatures: Dict[str, set] = {}
        self.post_warm: Dict[str, int] = {}
        self.warm = False

    def mark_warm(self) -> None:
        """Declare warmup over: every later novel signature is a
        *post-warm recompile* — shape churn, the thing steady-state
        serving must never do."""
        self.warm = True

    def observe(self, program: str, signature: Tuple,
                tracer=None) -> bool:
        """Record one invocation of ``program`` with argument shape
        ``signature``.  Returns True when the signature is new (i.e. XLA
        compiled).  Beyond each program's first signature — or any novel
        signature after :meth:`mark_warm` — a ``recompile`` warning
        event goes through ``tracer``."""
        sigs = self.signatures.setdefault(program, set())
        if signature in sigs:
            return False
        sigs.add(signature)
        if self.warm:
            self.post_warm[program] = self.post_warm.get(program, 0) + 1
        if tracer is not None and (self.warm or len(sigs) > 1):
            tracer.recompile(program, repr(signature), len(sigs),
                             post_warm=self.warm)
        return True

    @property
    def post_warm_recompiles(self) -> int:
        return sum(self.post_warm.values())

    def compiles(self, program: Optional[str] = None) -> int:
        if program is not None:
            return len(self.signatures.get(program, ()))
        return sum(len(s) for s in self.signatures.values())

    def churning_programs(self, threshold: int = 3) -> List[str]:
        """Programs with suspiciously many signatures — the triage list:
        find which argument's shape wobbles and pad it."""
        return sorted(p for p, s in self.signatures.items()
                      if len(s) >= threshold or self.post_warm.get(p, 0))

    def summary(self) -> Dict[str, object]:
        return {
            "warm": self.warm,
            "compiles_total": self.compiles(),
            "post_warm_recompiles": self.post_warm_recompiles,
            "programs": {p: {"signatures": len(s),
                             "post_warm": self.post_warm.get(p, 0)}
                         for p, s in sorted(self.signatures.items())},
            "churning": self.churning_programs(),
        }
