"""Optimizers (optax-like ``update(grads, state, params) -> (updates, state)``).

RMSProp is first-class because the paper's 3DGAN trains with RMSProp [29].
All states are pytrees of f32 master-precision tensors; updates are returned
in f32 and cast onto the param dtype by the caller (mixed-precision rule:
bf16 compute, f32 state).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


def _lr_at(lr: ScalarOrSchedule, count) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# SGD (+momentum)
# ---------------------------------------------------------------------------

def sgd(lr: ScalarOrSchedule, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        s = {"count": jnp.zeros((), jnp.int32)}
        if momentum:
            s["mu"] = _zeros_like_f32(params)
        return s

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step_lr = _lr_at(lr, state["count"])
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], g32)
            upd = jax.tree.map(lambda m: -step_lr * m, mu)
            return upd, {"count": state["count"] + 1, "mu": mu}
        return jax.tree.map(lambda g: -step_lr * g, g32), \
            {"count": state["count"] + 1}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# RMSProp (the paper's 3DGAN optimizer)
# ---------------------------------------------------------------------------

def rmsprop(lr: ScalarOrSchedule, decay: float = 0.9, eps: float = 1e-8,
            clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "nu": _zeros_like_f32(params)}

    def update(grads, state, params):
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step_lr = _lr_at(lr, state["count"])
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        nu = jax.tree.map(lambda n, g: decay * n + (1 - decay) * g * g,
                          state["nu"], g32)
        upd = jax.tree.map(lambda g, n: -step_lr * g / (jnp.sqrt(n) + eps),
                           g32, nu)
        return upd, {"count": state["count"] + 1, "nu": nu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def adamw(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": _zeros_like_f32(params),
                "nu": _zeros_like_f32(params)}

    def update(grads, state, params):
        gnorm = global_norm(grads)
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        step_lr = _lr_at(lr, state["count"])
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], g32)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g,
                          state["nu"], g32)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def u(m, n, p):
            upd = -step_lr * (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                upd = upd - step_lr * weight_decay * p.astype(jnp.float32)
            return upd

        upd = jax.tree.map(u, mu, nu, params)
        return upd, {"count": count, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def adam(lr: ScalarOrSchedule, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u).astype(p.dtype), params, updates)


def get(name: str, lr: ScalarOrSchedule, **kw) -> Optimizer:
    return {"sgd": sgd, "rmsprop": rmsprop, "adam": adam,
            "adamw": adamw}[name](lr, **kw)
