"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def schedule(count):
        c = count.astype(jnp.float32) if hasattr(count, "astype") \
            else jnp.asarray(count, jnp.float32)
        warm = peak * c / max(warmup_steps, 1)
        progress = jnp.clip((c - warmup_steps) /
                            max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(np.pi * progress))
        return jnp.where(c < warmup_steps, warm, cos)
    return schedule


def inverse_sqrt(peak: float, warmup_steps: int):
    def schedule(count):
        c = jnp.maximum(count.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(c / warmup_steps,
                                  jnp.sqrt(warmup_steps / c))
    return schedule
