from repro.optim.optimizers import (Optimizer, adam, adamw, apply_updates,
                                    clip_by_global_norm, get, global_norm,
                                    rmsprop, sgd)
from repro.optim import schedules  # noqa: F401
