"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Prefill/train uses the *chunked* SSD algorithm: within a chunk the SSD is
computed as a masked attention-like matmul (MXU-friendly quadratic-in-L
part), across chunks a linear state recurrence is carried by ``lax.scan``.
This is the TPU-native formulation: the GPU version's warp-level parallel
scan becomes (a) big dense intra-chunk matmuls on the MXU plus (b) a short
sequential scan over S/L chunk states — exactly the structure the Pallas
kernel in ``repro/kernels/ssd_scan.py`` tiles into VMEM (its grid is
sequential over chunks, the state lives in a VMEM accumulator).

Decode is the O(1) recurrent step over the (B, H, N, P) state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_num_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    w = cfg.ssm_conv_width
    conv_dim = di + 2 * G * N
    k1, k2, k3 = jax.random.split(key, 3)
    # in_proj -> [z(di), x(di), B(G*N), C(G*N), dt(H)]
    d_in_proj = 2 * di + 2 * G * N + H
    dt_target = jnp.exp(jnp.linspace(np.log(1e-3), np.log(1e-1), H))
    dt_init = jnp.log(jnp.expm1(dt_target))                       # softplus^-1
    return {
        "in_proj": nn.init_linear(k1, d, d_in_proj),
        "conv_w": nn.truncated_normal_init(k2, (w, conv_dim), 1.0 / np.sqrt(w)),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": nn.init_rmsnorm(di),
        "out_proj": nn.init_linear(k3, di, d),
    }


def _split_in_proj(cfg, zxbcdt):
    di, G, N, H = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xBC, dt


# ---------------------------------------------------------------------------
# Chunked SSD (reference; the Pallas kernel mirrors this tiling)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int,
                initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    x:  (b, S, H, P)   per-head inputs
    dt: (b, S, H)      positive step sizes (softplus applied by caller)
    A:  (H,)           negative per-head decay rates
    B:  (b, S, G, N)   input projections (G groups, broadcast to heads)
    C:  (b, S, G, N)   output projections
    Returns (y (b, S, H, P), final_state (b, H, N, P)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    L = min(chunk, S)
    S_orig = S
    if S % L != 0:
        # pad to a chunk multiple: dt=0 padding is inert (decay exp(0)=1,
        # zero input contribution), so state and outputs are unaffected
        pad = L - S % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // L
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)           # (b,S,H,N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    # reshape into chunks
    xc = xf.reshape(b, nc, L, H, P)
    dc = dtf.reshape(b, nc, L, H)
    Bc = Bf.reshape(b, nc, L, H, N)
    Cc = Cf.reshape(b, nc, L, H, N)

    da = dc * A[None, None, None, :]                              # (b,nc,L,H) log-decay
    cum = jnp.cumsum(da, axis=2)                                  # inclusive cumsum
    seg_total = cum[:, :, -1:, :]                                 # (b,nc,1,H)

    # ---- intra-chunk (quadratic in L, MXU) -------------------------------------
    # M[i,j] = C_i . B_j * exp(cum_i - cum_j) * dt_j   for i >= j
    scores = jnp.einsum("bclhn,bcmhn->bchlm", Cc, Bc)             # (b,nc,H,L,L)
    decay = jnp.exp(cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
                    - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3))
    mask = jnp.tril(jnp.ones((L, L), bool))
    gates = jnp.where(mask[None, None, None], decay, 0.0)
    M = scores * gates * dc.transpose(0, 1, 3, 2)[:, :, :, None, :]   # dt_j factor
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", M, xc)

    # ---- chunk states ------------------------------------------------------------
    # state contribution of chunk c: sum_j exp(seg_total - cum_j) * dt_j B_j x_j^T
    w = jnp.exp(seg_total - cum) * dc                             # (b,nc,L,H)
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp", w, Bc, xc)     # (b,nc,H,N,P)

    # ---- inter-chunk recurrence (sequential scan over nc) --------------------------
    seg_decay = jnp.exp(seg_total[:, :, 0, :])                    # (b,nc,H)
    h0 = (jnp.zeros((b, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h_prev, inp):
        dec, st = inp                                             # (b,H), (b,H,N,P)
        h_new = dec[:, :, None, None] * h_prev + st
        return h_new, h_prev                                      # emit state *entering* chunk

    _, h_enter = jax.lax.scan(
        step, h0, (jnp.moveaxis(seg_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                         # (b,nc,H,N,P)
    final_state = (seg_decay[:, -1, :, None, None] * h_enter[:, -1]
                   + states[:, -1])

    # ---- inter-chunk output: y_i += C_i . (exp(cum_i) * h_enter) --------------------
    y_inter = jnp.einsum("bclhn,bchnp->bclhp",
                         Cc * jnp.exp(cum)[..., None], h_enter)

    y = (y_intra + y_inter).reshape(b, S, H, P)[:, :S_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """One-token recurrence.

    state: (b, H, N, P); x: (b, H, P); dt: (b, H); B, C: (b, G, N).
    Returns (y (b, H, P), new_state).
    """
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=1)           # (b,H,N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A[None, :])                               # (b,H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dtf, Bf, x.astype(jnp.float32))
    new_state = dec[:, :, None, None] * state.astype(jnp.float32) + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cf, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv1d over the (x, B, C) channels
# ---------------------------------------------------------------------------

def causal_conv1d(xBC, conv_w, conv_b, conv_state=None):
    """xBC: (b, S, Cdim); conv_w: (w, Cdim).  Returns (out, new_conv_state).

    conv_state: (b, w-1, Cdim) trailing inputs from previous steps (decode).
    """
    w = conv_w.shape[0]
    xf = xBC.astype(jnp.float32)
    if conv_state is None:
        pad = jnp.zeros((xf.shape[0], w - 1, xf.shape[2]), jnp.float32)
    else:
        pad = conv_state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)                       # (b, S+w-1, C)
    out = sum(xp[:, i:i + xf.shape[1]] * conv_w[i][None, None]
              for i in range(w))
    out = out + conv_b[None, None]
    new_state = xp[:, -(w - 1):] if w > 1 else pad
    return jax.nn.silu(out).astype(xBC.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_block(params, cfg, x, cache=None):
    """x: (B, S, d_model).  cache: {"ssm": (B,H,N,P), "conv": (B,w-1,Cdim)}
    for one-token decode (S == 1).  Returns (out, new_cache)."""
    Bsz, S, d = x.shape
    di, H, N, G, P = (cfg.ssm_d_inner, cfg.ssm_num_heads, cfg.ssm_state,
                      cfg.ssm_groups, cfg.ssm_head_dim)
    dt_ = jnp.dtype(cfg.dtype)

    zxbcdt = nn.linear(params["in_proj"], x, dtype=dt_)
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])         # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))             # (H,)

    if cache is not None:
        xBC, new_conv = causal_conv1d(xBC, params["conv_w"], params["conv_b"],
                                      conv_state=cache["conv"])
        xs = xBC[..., :di].reshape(Bsz, 1, H, P)[:, 0]            # (B,H,P)
        Bmat = xBC[..., di:di + G * N].reshape(Bsz, G, N)
        Cmat = xBC[..., di + G * N:].reshape(Bsz, G, N)
        y, new_ssm = ssd_decode_step(cache["ssm"], xs, dt[:, 0], A, Bmat, Cmat)
        y = y + params["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(Bsz, 1, di).astype(dt_)
        new_cache = {"ssm": new_ssm.astype(cache["ssm"].dtype), "conv": new_conv.astype(cache["conv"].dtype)}
    else:
        xBC, _ = causal_conv1d(xBC, params["conv_w"], params["conv_b"])
        xs = xBC[..., :di].reshape(Bsz, S, H, P)
        Bmat = xBC[..., di:di + G * N].reshape(Bsz, S, G, N)
        Cmat = xBC[..., di + G * N:].reshape(Bsz, S, G, N)
        y, _ = ssd_chunked(xs, dt, A, Bmat, Cmat, cfg.ssm_chunk)
        y = (y.astype(jnp.float32)
             + params["D"].astype(jnp.float32)[None, None, :, None]
             * xs.astype(jnp.float32))
        y = y.reshape(Bsz, S, di).astype(dt_)
        new_cache = None

    # gated RMSNorm (Mamba2's norm-before-out-proj with silu(z) gate)
    y = nn.rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_),
                   eps=cfg.norm_eps)
    out = nn.linear(params["out_proj"], y, dtype=dt_)
    return out, new_cache


def init_mamba2_cache(cfg, batch: int, dtype=jnp.float32):
    H, N, P = cfg.ssm_num_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }
