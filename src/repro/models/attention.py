"""Attention: GQA + RoPE / M-RoPE + sliding-window + logit softcap + KV cache.

The prefill/train path is *query-chunked* (a lax.scan over query blocks with
per-chunk remat) so the S x S score matrix is never materialized — this is
what makes the 32k-prefill shapes memory-feasible, and it mirrors the tiling
of the Pallas flash-attention kernel (repro/kernels/flash_attention.py),
which is the TPU hot-path implementation validated against this reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# RoPE (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions, head_dim: int, theta: float, mrope_sections=None):
    """positions: (B, S) or (3, B, S) for M-RoPE.  Returns (B, S, head_dim/2)."""
    half = head_dim // 2
    freq_exponents = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = 1.0 / (theta ** freq_exponents)                  # (half,)
    if positions.ndim == 3:                                      # M-RoPE
        sections = mrope_sections
        assert sections is not None and sum(sections) == half, (sections, half)
        # section id per frequency: 0 -> temporal, 1 -> height, 2 -> width
        sec_id = np.repeat(np.arange(len(sections)), sections)   # (half,)
        pos = jnp.take(positions, jnp.asarray(sec_id), axis=0)   # (half, B, S)
        pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)       # (B, S, half)
        return pos * inv_freq[None, None, :]
    return positions.astype(jnp.float32)[..., None] * inv_freq[None, None, :]


def apply_rope(x, positions, theta: float = 10_000.0, mrope_sections=None):
    """x: (B, S, H, D); positions: (B, S) or (3, B, S)."""
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, x.shape[-1], theta, mrope_sections)
    cos = jnp.cos(ang)[:, :, None, :]                            # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kq, kk, kvk, ko = jax.random.split(key, 4)
    bias = cfg.qkv_bias and not cross
    return {
        "wq": nn.init_linear(kq, d, (h, dh), bias=bias),
        "wk": nn.init_linear(kk, d, (kv, dh), bias=bias),
        "wv": nn.init_linear(kvk, d, (kv, dh), bias=bias),
        "wo": nn.init_linear(ko, h * dh, d, stddev=1.0 / np.sqrt(h * dh)),
    }


# ---------------------------------------------------------------------------
# Core chunked GQA attention
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """Additive f32 bias.  q_pos: (Sq,) or (B, Sq); k_pos: (Skv,).

    Returns (Sq, Skv) or (B, Sq, Skv).
    """
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = k_pos[None, :].astype(jnp.int32)
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def attend(q, k, v, *, scale: float, causal: bool,
           window: Optional[int] = None, softcap_val: Optional[float] = None,
           q_positions=None, k_positions=None, q_chunk: int = 512):
    """Query-chunked attention.

    q: (B, Sq, KV, G, D); k, v: (B, Skv, KV, D).
    q_positions: (Sq,) or (B, Sq) absolute positions; k_positions: (Skv,).
    Returns (B, Sq, KV, G, D).
    """
    B, Sq = q.shape[0], q.shape[1]
    Skv = k.shape[1]
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if k_positions is None:
        k_positions = jnp.arange(Skv)

    def chunk_body(q_blk, qpos_blk):
        s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        s = nn.softcap(s, softcap_val)
        bias = _mask_bias(qpos_blk, k_positions, causal=causal, window=window)
        if bias.ndim == 3:                                       # batched positions
            bias = bias[:, None, None]                           # (B,1,1,Sq,Skv)
        p = jax.nn.softmax(s + bias, axis=-1)
        return jnp.einsum("bkgqt,btkd->bqkgd", p,
                          v.astype(p.dtype)).astype(q.dtype)

    if q_chunk <= 0 or Sq <= q_chunk or Sq % q_chunk != 0:
        return chunk_body(q, q_positions)

    n = Sq // q_chunk
    qs = jnp.moveaxis(q.reshape(B, n, q_chunk, *q.shape[2:]), 1, 0)
    if q_positions.ndim == 1:
        qpos = q_positions.reshape(n, q_chunk)
    else:
        qpos = jnp.moveaxis(q_positions.reshape(B, n, q_chunk), 1, 0)

    def scan_body(_, xs):
        qb, pb = xs
        # remat: the (qc x Skv) score tile is recomputed in the backward pass
        return None, jax.checkpoint(chunk_body)(qb, pb)

    _, out = jax.lax.scan(scan_body, None, (qs, qpos))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, *q.shape[2:])


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attend [+ cache])
# ---------------------------------------------------------------------------

def attention_block(params, cfg, x, *, positions=None, causal: bool = True,
                    window: Optional[int] = None, cache=None,
                    cache_index=None, kv_override=None, use_rope: bool = True,
                    block_tables=None, q_lens=None):
    """x: (B, S, d_model).  Returns (out, new_cache).

    positions: (B, S) or (3, B, S) for M-RoPE (defaults to broadcast arange).
    cache: {"k": (B, Smax, KV, D), "v": ...} — decode mode, S must be 1 and
      cache_index (B,) gives each sequence's write position.
    block_tables: (B, blocks_per_slot) int32 — paged mode: cache leaves
      are block storage {"k": (num_blocks, block_size, KV, D), ...}; this
      step's k/v are scattered to (table[b, pos//bs], pos%bs) and
      attention gathers through the table with the Pallas paged kernels.
      S == 1 is single-token decode; S > 1 is a *chunked-prefill* tile:
      row b's queries sit at absolute positions ``cache_index[b] + t``,
      their K/V land straight in the row's pool blocks (padding tokens —
      ``t >= q_lens[b]`` or positions past the table's extent — are
      routed to the storage's trailing trash block), and attention runs
      through the Pallas paged-prefill kernel.  No dense per-slot stripe
      is ever materialized.
    q_lens: (B,) int32, paged-prefill only — valid tokens per row of the
      chunk (None means all S).
    kv_override: (B, Skv, d) encoder output => cross-attention (no rope,
      no cache, bidirectional over kv).
    """
    B, S, _ = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kv
    dt = jnp.dtype(cfg.dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    q = nn.linear(params["wq"], x, dtype=dt)                     # (B,S,h,dh)
    kv_src = x if kv_override is None else kv_override.astype(dt)
    k = nn.linear(params["wk"], kv_src, dtype=dt)                # (B,Skv,kv,dh)
    v = nn.linear(params["wv"], kv_src, dtype=dt)

    if use_rope and kv_override is None:
        sections = cfg.mrope_sections if cfg.mrope else None
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        k = apply_rope(k, positions, cfg.rope_theta, sections)

    scale = cfg.attn_scale or (1.0 / np.sqrt(dh))
    q = q.reshape(B, S, kv, g, dh)
    sc = cfg.attn_logit_softcap

    new_cache = cache
    if cache is not None and block_tables is not None and kv_override is None:
        assert "k_scale" not in cache, "paged int8 KV unsupported"
        from repro.kernels import ops as kops
        idx = cache_index                                        # (B,) int32
        bs = cache["k"].shape[1]                                 # block size
        if S == 1:
            # paged decode: scatter this step's k/v into block storage
            # through the table, then gather-attend with the Pallas kernel
            rows = jnp.arange(B)
            blk = block_tables[rows, idx // bs]
            off = idx % bs
            upd_k = cache["k"].at[blk, off].set(
                k[:, 0].astype(cache["k"].dtype))
            upd_v = cache["v"].at[blk, off].set(
                v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": upd_k, "v": upd_v}
            # kernel casts tiles to f32 in VMEM; bf16 pages go in as-is
            out = kops.paged_decode_attention(
                q.reshape(B, S, h, dh), upd_k, upd_v, block_tables, idx + 1,
                window=window, softcap=sc, scale=scale)
            out = out.reshape(B, S, kv, g, dh)
        else:
            # paged chunked prefill: the whole (B, S) tile's k/v go
            # straight into each row's pool blocks; padding tokens (past
            # q_lens, or past the table's extent) land in the trailing
            # trash block, never a live page
            npages = cache["k"].shape[0]
            bps = block_tables.shape[1]
            qlv = (jnp.full((B,), S, jnp.int32) if q_lens is None
                   else q_lens.astype(jnp.int32))
            pos = idx[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
            valid = ((jnp.arange(S)[None] < qlv[:, None])
                     & (pos < bps * bs))
            rows = jnp.arange(B)[:, None]
            blk = jnp.where(
                valid,
                block_tables[rows, jnp.clip(pos // bs, 0, bps - 1)],
                npages - 1)
            off = pos % bs
            upd_k = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype))
            upd_v = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype))
            new_cache = {"k": upd_k, "v": upd_v}
            out = kops.paged_prefill_attention(
                q.reshape(B, S, h, dh), upd_k, upd_v, block_tables, idx,
                qlv, window=window, softcap=sc, scale=scale)
            out = out.reshape(B, S, kv, g, dh)
    elif cache is not None and kv_override is None:
        # decode: write this step's k/v at cache_index, attend over the cache
        assert S == 1, "cache mode is one-token decode"
        idx = cache_index                                        # (B,) int32
        rows = jnp.arange(B)
        if "k_scale" in cache:
            # int8 KV cache: per-(token, kv-head) absmax quantization
            def quantize(x1):                                    # (B, KV, D)
                s = jnp.max(jnp.abs(x1.astype(jnp.float32)),
                            axis=-1) / 127.0 + 1e-8              # (B, KV)
                q8 = jnp.round(x1.astype(jnp.float32)
                               / s[..., None]).astype(jnp.int8)
                return q8, s.astype(jnp.bfloat16)

            k8, ks = quantize(k[:, 0])
            v8, vs = quantize(v[:, 0])
            new_cache = {
                "k": cache["k"].at[rows, idx].set(k8),
                "v": cache["v"].at[rows, idx].set(v8),
                "k_scale": cache["k_scale"].at[rows, idx].set(ks),
                "v_scale": cache["v_scale"].at[rows, idx].set(vs),
            }
            kd = (new_cache["k"].astype(dt)
                  * new_cache["k_scale"].astype(dt)[..., None])
            vd = (new_cache["v"].astype(dt)
                  * new_cache["v_scale"].astype(dt)[..., None])
        else:
            upd_k = cache["k"].at[rows, idx].set(k[:, 0].astype(cache["k"].dtype))
            upd_v = cache["v"].at[rows, idx].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": upd_k, "v": upd_v}
            kd, vd = upd_k.astype(dt), upd_v.astype(dt)
        out = attend(q, kd, vd, scale=scale,
                     causal=True, window=window, softcap_val=sc,
                     q_positions=idx[:, None],
                     k_positions=jnp.arange(cache["k"].shape[1]),
                     q_chunk=cfg.attn_q_chunk)
    else:
        out = attend(q, k, v, scale=scale, causal=causal and kv_override is None,
                     window=window, softcap_val=sc, q_chunk=cfg.attn_q_chunk)

    out = nn.linear(params["wo"], out.reshape(B, S, h * dh), dtype=dt)
    return out, new_cache
