"""Mixture-of-Experts: top-k router + capacity-bounded sort-based dispatch.

TPU adaptation: instead of the GShard one-hot-einsum dispatch (whose
(tokens, experts, capacity) dispatch tensor is quadratically large for the
assigned 128-expert / 1M-token shapes), we use a *grouped sort-based*
dispatch:

  * tokens are processed in G groups (one group per sequence), so under the
    (data, model) mesh the per-group argsort/rank is local to the data
    shard — routing never forces a global gather of tokens;
  * within a group: top-k assignment -> argsort by expert id -> rank within
    expert via a max-scan -> scatter into a dense (E, C, d) buffer;
  * batched expert FFN: one einsum over the expert dim (MXU friendly, and
    the natural target for expert-parallel sharding of E over 'model' —
    XLA SPMD turns the buffer re-sharding into the paper-family all-to-all);
  * gather back + combine with renormalized router weights.

Memory is O(G * E * C_g * d) with C_g ~ tokens_per_group * k / E, matching
the activation footprint of the dense archs.  Tokens beyond an expert's
capacity are dropped (zero combine weight) — the standard capacity-factor
trade-off; the Switch-style aux loss pushes the router away from overflow.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


def init_moe(key, cfg):
    d, dff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, ki, kg, ko = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "router": nn.init_linear(kr, d, e),
        # expert-stacked gated MLP weights: leading dim = experts
        "wi": nn.truncated_normal_init(ki, (e, d, dff), s),
        "wg": nn.truncated_normal_init(kg, (e, d, dff), s),
        "wo": nn.truncated_normal_init(ko, (e, dff, d), 1.0 / np.sqrt(dff)),
    }


def expert_capacity(tokens_per_group: int, cfg) -> int:
    c = int(np.ceil(tokens_per_group * cfg.num_experts_per_tok
                    * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, int(np.ceil(c / 8)) * 8)          # pad to a lane-friendly size


def _dispatch_indices(eidx, capacity: int):
    """Per-group dispatch bookkeeping.

    eidx: (T, K) expert ids.  Returns (expert, slot_rank, keep) each (T*K,).
    """
    T, K = eidx.shape
    flat_e = eidx.reshape(T * K)
    order = jnp.argsort(flat_e)                                   # stable
    sorted_e = flat_e[order]
    # rank of each sorted slot within its expert segment
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (sorted_e[1:] != sorted_e[:-1]).astype(jnp.int32)])
    seg_start = jnp.where(is_start == 1, jnp.arange(T * K), 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    rank_sorted = jnp.arange(T * K) - seg_start
    rank = rank_sorted[jnp.argsort(order)]                        # undo the sort
    keep = rank < capacity
    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, capacity - 1)
    return safe_e, safe_r, keep


def moe_block(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Tokens are dispatched in contiguous groups of ~moe_group_size tokens
    (batch-major, so groups never straddle the batch/data sharding).  The
    group count adapts to the calling shape: train/prefill get ~4096-token
    groups; a decode batch collapses to ONE group so capacity padding does
    not explode (the §Perf fix for the MoE decode shapes).
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    dt = jnp.dtype(cfg.dtype)
    # choose a group size that divides T and is close to moe_group_size
    tokens_per_group = min(cfg.moe_group_size, T)
    while T % tokens_per_group != 0:
        tokens_per_group -= 1
    G = T // tokens_per_group
    xg = x.reshape(G, tokens_per_group, d)
    C = expert_capacity(tokens_per_group, cfg)

    Tg = tokens_per_group

    # ---- router (f32) --------------------------------------------------------
    logits = nn.linear(params["router"], xg.astype(jnp.float32),
                       dtype=jnp.float32)                         # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                          # (G, Tg, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # ---- aux load-balance loss (Switch-style, over all tokens) ----------------
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = cfg.router_aux_loss_coef * E * jnp.sum(me * ce)

    # ---- per-group dispatch -----------------------------------------------------
    safe_e, safe_r, keep = jax.vmap(
        lambda ei: _dispatch_indices(ei, C))(eidx)                # (G, Tg*K)

    tok_of_slot = jnp.arange(Tg * K) // K

    def scatter_group(xgr, eg, rg, kg):
        contrib = jnp.where(kg[:, None], xgr[tok_of_slot].astype(dt), 0)
        return jnp.zeros((E, C, d), dt).at[eg, rg].add(contrib)

    buf = jax.vmap(scatter_group)(xg, safe_e, safe_r, keep)       # (G, E, C, d)
    if cfg.moe_buffer_shard:
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(
            buf, P(None, cfg.moe_buffer_shard, None, None))

    # ---- batched expert FFN (E is the expert-parallel axis) ----------------------
    a = nn.activation(cfg.act)
    hg = jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(dt))
    hi = jnp.einsum("gecd,edf->gecf", buf, params["wi"].astype(dt))
    ho = jnp.einsum("gecf,efd->gecd", a(hg) * hi, params["wo"].astype(dt))

    # ---- gather back + combine ------------------------------------------------------
    def gather_group(hog, eg, rg):
        return hog[eg, rg]                                        # (Tg*K, d)

    slot_out = jax.vmap(gather_group)(ho, safe_e, safe_r)         # (G, Tg*K, d)
    w = jnp.where(keep, gate.reshape(G, Tg * K), 0.0)
    out = jnp.sum((slot_out.astype(jnp.float32)
                   * w[..., None]).reshape(G, Tg, K, d), axis=2)
    return out.reshape(B, S, d).astype(x.dtype), aux
