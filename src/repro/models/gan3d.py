"""3DGAN — the paper's production workload (§IV-A, refs [21-28]).

A 3-D convolutional auxiliary-classifier GAN simulating electromagnetic
calorimeter showers: the generator maps (latent, primary energy) to a
25x25x25 energy-deposition image; the discriminator outputs a real/fake
logit plus auxiliary regressions (primary energy, total deposition) that
condition the training — "loosely following an auxiliary classifier GAN
approach ... with a custom loss function; overall it sums up to slightly
less than 1 million parameters", trained with RMSProp [29].

The training loop lives in ``examples/train_3dgan.py`` and runs under the
paper-faithful Horovod-DP engine (repro.core.hvd) inside a deployment
capsule — the full SuperMUC-NG pipeline.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


@dataclass(frozen=True)
class GAN3DConfig:
    name: str = "3dgan"
    grid: int = 25
    latent_dim: int = 200
    g_fc_ch: int = 10            # channels of the 7x7x7 seed volume
    g_base: int = 32
    d_base: int = 16
    e_scale: float = 100.0       # energy normalization (GeV)
    # loss weights (adversarial, energy regression, total-deposition)
    w_adv: float = 1.0
    w_energy: float = 0.1
    w_ecal: float = 0.1


# ---------------------------------------------------------------------------
# conv3d helpers
# ---------------------------------------------------------------------------

_DN = ("NDHWC", "DHWIO", "NDHWC")


def init_conv3d(key, k: int, cin: int, cout: int):
    w = nn.truncated_normal_init(key, (k, k, k, cin, cout),
                                 1.0 / np.sqrt(k ** 3 * cin))
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def conv3d(p, x, stride: int = 1, padding: str = "SAME"):
    dn = jax.lax.conv_dimension_numbers(x.shape, p["w"].shape, _DN)
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride,) * 3, padding,
        dimension_numbers=dn)
    return y + p["b"].astype(x.dtype)


def _upsample2(x):
    for axis in (1, 2, 3):
        x = jnp.repeat(x, 2, axis=axis)
    return x


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------

def init_generator(key, cfg: GAN3DConfig):
    ks = jax.random.split(key, 6)
    b = cfg.g_base
    return {
        "fc": nn.init_linear(ks[0], cfg.latent_dim + 1, 7 * 7 * 7 * cfg.g_fc_ch,
                             bias=True),
        "c1": init_conv3d(ks[1], 3, cfg.g_fc_ch, b),
        "c2": init_conv3d(ks[2], 3, b, b // 2),
        "c3": init_conv3d(ks[3], 3, b // 2, b // 4),
        "c4": init_conv3d(ks[4], 3, b // 4, 1),
    }


def generator(params, cfg: GAN3DConfig, z, energy):
    """z: (B, latent); energy: (B,) GeV -> image (B, G, G, G, 1) (>= 0)."""
    e = (energy / cfg.e_scale)[:, None].astype(z.dtype)
    h = nn.linear(params["fc"], jnp.concatenate([z, e], axis=1))
    h = jax.nn.leaky_relu(h, 0.2).reshape(-1, 7, 7, 7, cfg.g_fc_ch)
    h = jax.nn.leaky_relu(conv3d(params["c1"], h), 0.2)
    h = _upsample2(h)                                   # 14^3
    h = jax.nn.leaky_relu(conv3d(params["c2"], h), 0.2)
    h = _upsample2(h)                                   # 28^3
    h = jax.nn.leaky_relu(conv3d(params["c3"], h), 0.2)
    h = h[:, :cfg.grid, :cfg.grid, :cfg.grid]           # crop to 25^3
    # softplus: energies >= 0 without the dead-ReLU collapse mode
    img = jax.nn.softplus(conv3d(params["c4"], h))
    # scale with requested primary energy (physics conditioning)
    return img * (energy[:, None, None, None, None] / cfg.e_scale)


# ---------------------------------------------------------------------------
# Discriminator (ACGAN: validity + auxiliary regressions)
# ---------------------------------------------------------------------------

def init_discriminator(key, cfg: GAN3DConfig):
    ks = jax.random.split(key, 8)
    b = cfg.d_base
    flat = 4 * 4 * 4 * 4 * b
    return {
        "c1": init_conv3d(ks[0], 5, 1, b),
        "c2": init_conv3d(ks[1], 5, b, 2 * b),
        "c3": init_conv3d(ks[2], 5, 2 * b, 4 * b),
        "head_adv": nn.init_linear(ks[3], flat, 1, bias=True),
        "head_energy": nn.init_linear(ks[4], flat, 1, bias=True),
        "head_ecal": nn.init_linear(ks[5], flat, 1, bias=True),
    }


def discriminator(params, cfg: GAN3DConfig, img):
    """img: (B, G, G, G, 1) -> dict(adv_logit, energy_pred, ecal_pred)."""
    x = jnp.log1p(img)                                   # dynamic-range squash
    h = jax.nn.leaky_relu(conv3d(params["c1"], x, stride=2), 0.2)   # 13^3
    h = jax.nn.leaky_relu(conv3d(params["c2"], h, stride=2), 0.2)   # 7^3
    h = jax.nn.leaky_relu(conv3d(params["c3"], h, stride=2), 0.2)   # 4^3
    h = h.reshape(h.shape[0], -1)
    return {
        "adv_logit": nn.linear(params["head_adv"], h)[:, 0],
        "energy_pred": jax.nn.relu(nn.linear(params["head_energy"], h))[:, 0]
        * cfg.e_scale,
        "ecal_pred": jax.nn.relu(nn.linear(params["head_ecal"], h))[:, 0]
        * cfg.e_scale,
    }


# ---------------------------------------------------------------------------
# Losses (the paper's custom multi-term loss)
# ---------------------------------------------------------------------------

def _bce(logit, target):
    # one-sided label smoothing on the real label (GAN stabilizer)
    target = jnp.minimum(target, 0.9)
    return jnp.mean(jnp.maximum(logit, 0) - logit * target
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def _mape(pred, true):
    return jnp.mean(jnp.abs(pred - true) / (jnp.abs(true) + 1e-3))


def d_loss(d_params, g_params, cfg: GAN3DConfig, batch, z):
    real, energy = batch["images"], batch["energies"]
    fake = generator(g_params, cfg, z, energy)
    out_r = discriminator(d_params, cfg, real)
    out_f = discriminator(d_params, cfg, jax.lax.stop_gradient(fake))
    ecal_true = jnp.sum(real, axis=(1, 2, 3, 4))
    loss = (cfg.w_adv * (_bce(out_r["adv_logit"], 1.0)
                         + _bce(out_f["adv_logit"], 0.0))
            + cfg.w_energy * _mape(out_r["energy_pred"], energy)
            + cfg.w_ecal * _mape(out_r["ecal_pred"], ecal_true))
    acc_real = jnp.mean((out_r["adv_logit"] > 0).astype(jnp.float32))
    acc_fake = jnp.mean((out_f["adv_logit"] < 0).astype(jnp.float32))
    return loss, {"d_loss": loss, "acc_real": acc_real, "acc_fake": acc_fake}


def g_loss(g_params, d_params, cfg: GAN3DConfig, batch, z):
    energy = batch["energies"]
    fake = generator(g_params, cfg, z, energy)
    out_f = discriminator(d_params, cfg, fake)
    ecal_fake = jnp.sum(fake, axis=(1, 2, 3, 4))
    # generator wants: fool the adversary AND respect the physics heads
    loss = (cfg.w_adv * _bce(out_f["adv_logit"], 1.0)
            + cfg.w_energy * _mape(out_f["energy_pred"], energy)
            + cfg.w_ecal * _mape(out_f["ecal_pred"], ecal_fake))
    return loss, {"g_loss": loss,
                  "fake_total_e": jnp.mean(ecal_fake)}


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
