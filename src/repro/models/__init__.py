from repro.models import attention, modules, moe, ssm, transformer  # noqa: F401
