"""Low-level parameterized modules (pure-functional, pytree params).

Params are nested dicts of jnp arrays.  Every ``init_*`` takes a PRNG key
and returns a params pytree; every ``apply``-style function is pure.
Compute happens in ``cfg.dtype`` (bf16 by default); params are stored in
``cfg.param_dtype`` (f32 master copies).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out, bias: bool = False, dtype=jnp.float32,
                stddev: Optional[float] = None):
    """d_out may be an int or a tuple (e.g. (heads, head_dim))."""
    out_shape = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    if stddev is None:
        stddev = 1.0 / np.sqrt(d_in)
    p = {"w": truncated_normal_init(key, (d_in, *out_shape), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def linear(p, x, dtype=None):
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    n_out = w.ndim - 1
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def linear_nd_in(p, x, n_in: int, dtype=None):
    """Linear contracting the last ``n_in`` dims of x (e.g. (heads, head_dim))."""
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    axes_x = tuple(range(x.ndim - n_in, x.ndim))
    axes_w = tuple(range(n_in))
    y = jax.lax.dot_general(x, w, ((axes_x, axes_w), ((), ())),
                            preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}        # (1 + scale) parameterization


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    # GPT-style small init: keeps tied-unembedding logits O(1) at init
    return {"table": truncated_normal_init(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens, dtype=None):
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, tokens, axis=0)


def unembed(p, x):
    return jax.lax.dot_general(
        x, p["table"].astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Activations / misc
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Gated MLP (llama-style; used by all dense archs and as the expert FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_linear(k1, d_model, d_ff, dtype=dtype),
        "wg": init_linear(k2, d_model, d_ff, dtype=dtype),
        "wo": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p, x, act: str = "silu", dtype=None):
    a = activation(act)
    h = a(linear(p["wg"], x, dtype)) * linear(p["wi"], x, dtype)
    return linear(p["wo"], h, dtype)
