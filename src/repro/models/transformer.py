"""Composable transformer stack covering all assigned architecture families.

Families:
  dense   — llama-style pre-norm GQA decoder (+ gemma2 local/global pattern,
            logit softcaps, post-block norms; qwen2 QKV bias)
  moe     — dense attention + top-k MoE FFN (dbrx, qwen3-moe)
  ssm     — Mamba2 (SSD) stack, attention-free
  hybrid  — Mamba2 backbone + one weight-SHARED attention block applied
            every ``hybrid_attn_every`` layers (zamba2)
  encdec  — whisper: bidirectional encoder over stub frame embeddings +
            causal decoder with cross-attention, LayerNorm/GELU, learned
            position embeddings
  vlm     — qwen2-vl backbone: stub patch embeddings prepended, M-RoPE

Layers are *stacked* (params carry a leading layer dim) and executed with
``lax.scan`` so the compiled HLO contains ONE layer body regardless of
depth — this is what keeps the 46–80-layer dry-run compiles tractable and
the activation footprint flat (one rematted layer live at a time).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import modules as nn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _init_norm(cfg):
    return (nn.init_layernorm(cfg.d_model) if cfg.norm_type == "layernorm"
            else nn.init_rmsnorm(cfg.d_model))


def _norm(cfg, p, x):
    return (nn.layernorm(p, x, cfg.norm_eps) if cfg.norm_type == "layernorm"
            else nn.rmsnorm(p, x, cfg.norm_eps))


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _init_attn_mlp_layer(cfg, key, cross: bool = False, kind: str = "dense"):
    ks = jax.random.split(key, 8)
    p = {"ln1": _init_norm(cfg), "ln2": _init_norm(cfg),
         "attn": attn.init_attention(ks[0], cfg)}
    if cross:
        p["ln_cross"] = _init_norm(cfg)
        p["cross_attn"] = attn.init_attention(ks[1], cfg, cross=True)
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    else:
        p["mlp"] = nn.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    if cfg.post_block_norm:
        p["post_ln1"] = _init_norm(cfg)
        p["post_ln2"] = _init_norm(cfg)
    return p


def _init_mamba_layer(cfg, key):
    return {"ln1": _init_norm(cfg), "mamba": ssm_lib.init_mamba2(key, cfg)}


def _stack_init(fn, keys):
    return jax.vmap(fn)(keys)


# ---------------------------------------------------------------------------
# Per-layer apply
# ---------------------------------------------------------------------------

def _apply_attn_mlp_layer(p, cfg, x, *, window, positions=None, causal=True,
                          cache=None, cache_index=None, encoder_out=None,
                          use_rope=True, block_tables=None, q_lens=None):
    """Pre-norm attention + (cross-attention) + MLP/MoE.  Returns
    (x, new_cache, aux)."""
    h = _norm(cfg, p["ln1"], x)
    a, new_cache = attn.attention_block(
        p["attn"], cfg, h, positions=positions, causal=causal, window=window,
        cache=cache, cache_index=cache_index, use_rope=use_rope,
        block_tables=block_tables, q_lens=q_lens)
    if cfg.post_block_norm:
        a = _norm(cfg, p["post_ln1"], a)
    x = x + a
    if encoder_out is not None:
        h = _norm(cfg, p["ln_cross"], x)
        c, _ = attn.attention_block(p["cross_attn"], cfg, h,
                                    kv_override=encoder_out, use_rope=False)
        x = x + c
    h = _norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = moe_lib.moe_block(p["moe"], cfg, h)
    else:
        m = nn.mlp(p["mlp"], h, cfg.act, dtype=jnp.dtype(cfg.dtype))
    if cfg.post_block_norm:
        m = _norm(cfg, p["post_ln2"], m)
    return x + m, new_cache, aux


def _apply_mamba_layer(p, cfg, x, cache=None):
    h = _norm(cfg, p["ln1"], x)
    m, new_cache = ssm_lib.mamba2_block(p["mamba"], cfg, h, cache=cache)
    return x + m, new_cache


# ---------------------------------------------------------------------------
# Layer pattern: windows per position in the scan group
# ---------------------------------------------------------------------------

def layer_pattern(cfg, long_context: bool = False):
    """Returns a tuple of window sizes (None = full attention), one entry per
    layer inside a scan group.  gemma2 alternates (local, global)."""
    if cfg.local_global_pattern:
        g_win = cfg.long_context_window if long_context else None
        return (cfg.sliding_window, g_win)
    win = cfg.sliding_window
    if long_context and cfg.long_context_window is not None:
        win = cfg.long_context_window if win is None else win
    return (win,)


# ---------------------------------------------------------------------------
# Parameter init (full model)
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": nn.init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": _init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.init_linear(keys[1], cfg.d_model, cfg.vocab_size)
    if cfg.max_pos_embed:
        p["pos_embed"] = nn.truncated_normal_init(
            keys[2], (cfg.max_pos_embed, cfg.d_model), 0.02)

    L = cfg.num_layers
    if cfg.family in ("dense", "vlm", "moe"):
        pat = len(layer_pattern(cfg))
        assert L % pat == 0, (L, pat)
        lk = jax.random.split(keys[3], L).reshape(L // pat, pat, 2)
        kind = "moe" if cfg.family == "moe" else "dense"
        p["layers"] = _stack_init(
            jax.vmap(lambda k: _init_attn_mlp_layer(cfg, k, kind=kind)), lk)
    elif cfg.family == "ssm":
        lk = jax.random.split(keys[3], L)
        p["layers"] = _stack_init(lambda k: _init_mamba_layer(cfg, k), lk)
    elif cfg.family == "hybrid":
        E = cfg.hybrid_attn_every
        G, R = L // E, L % E
        gk = jax.random.split(keys[3], G * E).reshape(G, E, 2)
        p["layers"] = _stack_init(
            jax.vmap(lambda k: _init_mamba_layer(cfg, k)), gk)
        if R:
            rk = jax.random.split(keys[4], R)
            p["tail_layers"] = _stack_init(lambda k: _init_mamba_layer(cfg, k), rk)
        p["shared_attn"] = _init_attn_mlp_layer(cfg, keys[5])
    elif cfg.family == "encdec":
        ek = jax.random.split(keys[3], cfg.encoder_layers)
        p["encoder"] = {
            "layers": _stack_init(
                lambda k: _init_attn_mlp_layer(cfg, k), ek),
            "final_norm": _init_norm(cfg),
            "pos_embed": nn.truncated_normal_init(
                keys[6], (cfg.encoder_seq, cfg.d_model), 0.02),
        }
        dk = jax.random.split(keys[4], L)
        p["layers"] = _stack_init(
            lambda k: _init_attn_mlp_layer(cfg, k, cross=True), dk)
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# KV / SSM cache
# ---------------------------------------------------------------------------

def _cache_struct(cfg, batch: int, seq_len: int):
    """Nested dict of (shape, dtype) tuples describing the decode cache."""
    kv = cfg.num_kv_heads
    dh = cfg.resolved_head_dim if cfg.num_heads else 0
    L = cfg.num_layers

    def attn_cache(lead):
        if cfg.kv_cache_dtype == "int8":
            return {"k": (lead + (batch, seq_len, kv, dh), jnp.int8),
                    "v": (lead + (batch, seq_len, kv, dh), jnp.int8),
                    "k_scale": (lead + (batch, seq_len, kv), jnp.bfloat16),
                    "v_scale": (lead + (batch, seq_len, kv), jnp.bfloat16)}
        return {"k": (lead + (batch, seq_len, kv, dh), jnp.bfloat16),
                "v": (lead + (batch, seq_len, kv, dh), jnp.bfloat16)}

    def mamba_cache(lead):
        H, N, P = cfg.ssm_num_heads, cfg.ssm_state, cfg.ssm_head_dim
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {"ssm": (lead + (batch, H, N, P), jnp.float32),
                "conv": (lead + (batch, cfg.ssm_conv_width - 1, conv_dim),
                         jnp.float32)}

    if cfg.family in ("dense", "vlm", "moe"):
        pat = len(layer_pattern(cfg))
        return {"layers": attn_cache((L // pat, pat))}
    if cfg.family == "ssm":
        return {"layers": mamba_cache((L,))}
    if cfg.family == "hybrid":
        E = cfg.hybrid_attn_every
        G, R = L // E, L % E
        c = {"layers": mamba_cache((G, E)), "shared_attn": attn_cache((G,))}
        if R:
            c["tail_layers"] = mamba_cache((R,))
        return c
    if cfg.family == "encdec":
        return {"layers": attn_cache((L,))}
    raise ValueError(cfg.family)


def init_cache_specs(cfg, batch: int, seq_len: int):
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(*sd),
                        _cache_struct(cfg, batch, seq_len),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


def init_cache(cfg, batch: int, seq_len: int):
    return jax.tree.map(lambda sd: jnp.zeros(*sd),
                        _cache_struct(cfg, batch, seq_len),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# Scan helpers
# ---------------------------------------------------------------------------

def _scan_layers(body, x0, stacked, length_axis_trees, remat: bool,
                 scan: bool = True, policy: str = "nothing"):
    """Scan ``body(carry, layer_slice)`` over the leading dim of ``stacked``.

    length_axis_trees: extra trees scanned alongside (e.g. caches); pass ()
    if none.  Returns (final_carry, stacked_outputs).

    scan=False unrolls into a python loop over layer slices (identical
    math and param layout) — used by the roofline pass because XLA's
    cost_analysis counts a while-loop body once, not x trip-count.
    """
    if remat:
        pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
               if policy == "dots"
               else jax.checkpoint_policies.nothing_saveable)
        fn = jax.checkpoint(body, policy=pol)
    else:
        fn = body
    if scan:
        return jax.lax.scan(fn, x0, (stacked, *length_axis_trees))
    length = jax.tree.leaves(stacked)[0].shape[0]
    carry, ys = x0, []
    for i in range(length):
        xs = jax.tree.map(lambda a: a[i], (stacked, *length_axis_trees))
        carry, y = fn(carry, xs)
        ys.append(y)
    stacked_ys = (None if all(y is None for y in ys)
                  else jax.tree.map(lambda *a: jnp.stack(a), *ys))
    return carry, stacked_ys


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg, batch: Dict[str, Any], *,
            long_context: bool = False,
            last_only: bool = False,
            return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits (B, S, V) f32, aux_loss).

    last_only: unembed only the final position (prefill serving) — avoids
    materializing the (B, S, V) logits tensor.
    return_hidden: return the final-norm hidden states instead of logits
    (used by the chunked-CE loss).
    """
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B = tokens.shape[0]

    x = nn.embed(params["embed"], tokens, dtype=dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)

    positions = None
    encoder_out = None
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patch_embeddings"].astype(dt), x], axis=1)
        positions = batch["mrope_positions"]
    S = x.shape[1]
    if cfg.max_pos_embed:
        x = x + params["pos_embed"][:S][None].astype(dt)
    if cfg.family == "encdec":
        encoder_out = _encode(params["encoder"], cfg, batch["encoder_input"])

    windows = layer_pattern(cfg, long_context)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        use_rope = cfg.max_pos_embed == 0

        def body(carry, xs):
            x, aux = carry
            (group_params,) = xs
            for i, win in enumerate(windows if cfg.family != "encdec" else (None,)):
                lp = jax.tree.map(lambda a: a[i], group_params) \
                    if cfg.family != "encdec" else group_params
                x, _, a = _apply_attn_mlp_layer(
                    lp, cfg, x, window=win, positions=positions,
                    encoder_out=encoder_out, use_rope=use_rope)
                aux = aux + a
            return (x, aux), None

        if cfg.family == "encdec":
            stacked = params["layers"]
        else:
            stacked = params["layers"]
        (x, aux_total), _ = _scan_layers(body, (x, aux_total), stacked, (),
                                         cfg.remat, cfg.scan_layers,
                                         cfg.remat_policy)
    elif cfg.family == "ssm":
        def body(carry, xs):
            (lp,) = xs
            x, _ = _apply_mamba_layer(lp, cfg, carry)
            return x, None
        x, _ = _scan_layers(body, x, params["layers"], (), cfg.remat,
                            cfg.scan_layers, cfg.remat_policy)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        win = cfg.long_context_window if long_context else None

        def body(carry, xs):
            (gp,) = xs
            x = carry
            for i in range(cfg.hybrid_attn_every):
                lp = jax.tree.map(lambda a: a[i], gp)
                x, _ = _apply_mamba_layer(lp, cfg, x)
            x, _, _ = _apply_attn_mlp_layer(shared, cfg, x, window=win)
            return x, None

        x, _ = _scan_layers(body, x, params["layers"], (), cfg.remat,
                            cfg.scan_layers, cfg.remat_policy)
        if "tail_layers" in params:
            def tail_body(carry, xs):
                (lp,) = xs
                x, _ = _apply_mamba_layer(lp, cfg, carry)
                return x, None
            x, _ = _scan_layers(tail_body, x, params["tail_layers"], (),
                                cfg.remat, cfg.scan_layers, cfg.remat_policy)
    else:
        raise ValueError(cfg.family)

    if last_only:
        x = x[:, -1:]
    x = _norm(cfg, params["final_norm"], x)
    if return_hidden:
        return x, aux_total
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.linear(params["lm_head"], x, dtype=dt).astype(jnp.float32))
    logits = nn.softcap(logits, cfg.final_logit_softcap)
    return logits, aux_total


def _encode(enc_params, cfg, encoder_input):
    dt = jnp.dtype(cfg.dtype)
    x = encoder_input.astype(dt)
    x = x + enc_params["pos_embed"][:x.shape[1]][None].astype(dt)

    def body(carry, xs):
        (lp,) = xs
        x, _, _ = _apply_attn_mlp_layer(lp, cfg, carry, window=None,
                                        causal=False, use_rope=False)
        return x, None

    x, _ = _scan_layers(body, x, enc_params["layers"], (), cfg.remat,
                        cfg.scan_layers, cfg.remat_policy)
    return _norm(cfg, enc_params["final_norm"], x)


# ---------------------------------------------------------------------------
# Decode step (one token against a seq_len cache)
# ---------------------------------------------------------------------------

def decode_step(params, cfg, batch: Dict[str, Any], *,
                long_context: bool = False) -> Tuple[jnp.ndarray, Any]:
    """One-token decode.  batch: tokens (B,1), positions (B,), cache, plus
    encoder_output / mrope_positions when applicable.  With
    ``block_tables`` (B, blocks_per_slot) in the batch, the attention
    cache leaves are block storage and K/V are gathered through the
    tables (paged attention) instead of the dense per-slot layout.
    Returns (logits (B, 1, V) f32, new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    tokens, idx, cache = batch["tokens"], batch["positions"], batch["cache"]
    B = tokens.shape[0]

    x = nn.embed(params["embed"], tokens, dtype=dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    if cfg.max_pos_embed:
        x = x + params["pos_embed"].astype(dt)[idx][:, None]

    positions = batch.get("mrope_positions")
    if positions is None:
        positions = idx[:, None]                                   # (B,1)
    encoder_out = batch.get("encoder_output")
    block_tables = batch.get("block_tables")
    windows = layer_pattern(cfg, long_context)
    use_rope = cfg.max_pos_embed == 0
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        def body(x, xs):
            if cfg.family == "encdec":
                lp, lc = xs
                x, nc, _ = _apply_attn_mlp_layer(
                    lp, cfg, x, window=None, positions=positions, cache=lc,
                    cache_index=idx, encoder_out=encoder_out, use_rope=use_rope)
            else:
                gp, gc = xs
                ncs = []
                for i, win in enumerate(windows):
                    lp = jax.tree.map(lambda a: a[i], gp)
                    lc = jax.tree.map(lambda a: a[i], gc)
                    x, nc_i, _ = _apply_attn_mlp_layer(
                        lp, cfg, x, window=win, positions=positions, cache=lc,
                        cache_index=idx, use_rope=use_rope,
                        block_tables=block_tables)
                    ncs.append(nc_i)
                nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
            return x, nc

        x, nc = _scan_layers(body, x, params["layers"], (cache["layers"],),
                             False, cfg.scan_layers)
        new_cache["layers"] = nc
    elif cfg.family == "ssm":
        def body(x, xs):
            lp, lc = xs
            x, nc = _apply_mamba_layer(lp, cfg, x, cache=lc)
            return x, nc
        x, nc = _scan_layers(body, x, params["layers"], (cache["layers"],),
                             False, cfg.scan_layers)
        new_cache["layers"] = nc
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        win = cfg.long_context_window if long_context else None

        def body(x, xs):
            gp, gc, ac = xs
            ncs = []
            for i in range(cfg.hybrid_attn_every):
                lp = jax.tree.map(lambda a: a[i], gp)
                lc = jax.tree.map(lambda a: a[i], gc)
                x, nc_i = _apply_mamba_layer(lp, cfg, x, cache=lc)
                ncs.append(nc_i)
            x, nac, _ = _apply_attn_mlp_layer(
                shared, cfg, x, window=win, positions=positions, cache=ac,
                cache_index=idx)
            return x, (jax.tree.map(lambda *a: jnp.stack(a), *ncs), nac)

        x, (nc, nac) = _scan_layers(
            body, x, params["layers"],
            (cache["layers"], cache["shared_attn"]), False, cfg.scan_layers)
        new_cache["layers"], new_cache["shared_attn"] = nc, nac
        if "tail_layers" in params:
            def tail(x, xs):
                lp, lc = xs
                x, nc = _apply_mamba_layer(lp, cfg, x, cache=lc)
                return x, nc
            x, ntc = _scan_layers(tail, x, params["tail_layers"],
                                  (cache["tail_layers"],), False,
                                  cfg.scan_layers)
            new_cache["tail_layers"] = ntc
    else:
        raise ValueError(cfg.family)

    x = _norm(cfg, params["final_norm"], x)
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.linear(params["lm_head"], x, dtype=dt).astype(jnp.float32))
    logits = nn.softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged chunked-prefill step (a (B, C) query tile against block storage)
# ---------------------------------------------------------------------------

def prefill_step(params, cfg, batch: Dict[str, Any], *,
                 long_context: bool = False) -> Tuple[jnp.ndarray, Any]:
    """One chunk of batched paged prefill: ``C`` tokens for every row.

    batch: tokens (B, C); positions (B,) — each row's *start* position
    (row b's token t sits at absolute position ``positions[b] + t``);
    q_lens (B,) — valid tokens per row (padding tokens and whole padding
    rows are masked and their K/V routed to the trash block); cache —
    paged block storage; block_tables (B, blocks_per_slot).

    The chunk's K/V are written straight into each row's pool blocks and
    attention gathers the full history (earlier chunks + this one)
    through the tables with the Pallas paged-prefill kernel, so paged
    prefill never materializes a dense ``max_seq_len`` stripe.  Returns
    (logits (B, C, V) f32, new_cache); logits at padding positions are
    garbage — callers index only real tokens.
    """
    dt = jnp.dtype(cfg.dtype)
    tokens, starts, cache = batch["tokens"], batch["positions"], batch["cache"]
    q_lens = batch["q_lens"]
    tables = batch["block_tables"]
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(
            f"paged prefill needs a positional attention cache; family "
            f"{cfg.family!r} unsupported")
    B, C = tokens.shape
    positions = (starts.astype(jnp.int32)[:, None]
                 + jnp.arange(C, dtype=jnp.int32)[None])           # (B, C)

    x = nn.embed(params["embed"], tokens, dtype=dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    if cfg.max_pos_embed:
        safe = jnp.clip(positions, 0, cfg.max_pos_embed - 1)
        x = x + params["pos_embed"].astype(dt)[safe]

    windows = layer_pattern(cfg, long_context)
    use_rope = cfg.max_pos_embed == 0
    new_cache = dict(cache)

    def body(x, xs):
        gp, gc = xs
        ncs = []
        for i, win in enumerate(windows):
            lp = jax.tree.map(lambda a: a[i], gp)
            lc = jax.tree.map(lambda a: a[i], gc)
            x, nc_i, _ = _apply_attn_mlp_layer(
                lp, cfg, x, window=win, positions=positions, cache=lc,
                cache_index=starts, use_rope=use_rope,
                block_tables=tables, q_lens=q_lens)
            ncs.append(nc_i)
        return x, jax.tree.map(lambda *a: jnp.stack(a), *ncs)

    x, nc = _scan_layers(body, x, params["layers"], (cache["layers"],),
                         False, cfg.scan_layers)
    new_cache["layers"] = nc

    x = _norm(cfg, params["final_norm"], x)
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.linear(params["lm_head"], x, dtype=dt).astype(jnp.float32))
    logits = nn.softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(params, cfg, batch: Dict[str, Any], *,
            long_context: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Next-token cross-entropy (+ MoE aux).  Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, batch, long_context=long_context)
    labels = batch["labels"]
    if cfg.family == "vlm":
        npatch = cfg.num_patches
        logits = logits[:, npatch:]
        labels = labels[:, npatch:]
    # shift: logits[t] predicts labels[t+1]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = labels[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss + aux
    return total, {"ce_loss": loss, "aux_loss": aux,
                   "perplexity": jnp.exp(loss)}


# ---------------------------------------------------------------------------
# Chunked (fused) cross-entropy — beyond-paper memory optimization
# ---------------------------------------------------------------------------

def lm_loss_chunked(params, cfg, batch: Dict[str, Any], *,
                    long_context: bool = False,
                    seq_chunk: int = 512) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """CE loss without materializing the (B, S, V) logits tensor.

    Runs the trunk once, then scans over SEQUENCE chunks: each chunk
    unembeds (B, c, V), computes logsumexp + the target logit, and is
    rematerialized in the backward pass.  Peak activation memory for the
    loss drops from O(B*S*V) to O(B*seq_chunk*V) — the §Perf lever for the
    256k-vocab gemma2 train shapes.
    """
    dt = jnp.dtype(cfg.dtype)
    # --- trunk (same as forward, but stop before unembedding) -------------
    trunk_batch = dict(batch)
    labels = batch["labels"]

    x, aux = _trunk(params, cfg, trunk_batch, long_context=long_context)
    if cfg.family == "vlm":
        npatch = cfg.num_patches
        x = x[:, npatch:]
        labels = labels[:, npatch:]
    B, S, _ = x.shape
    x = x[:, :-1]
    tgt = labels[:, 1:]
    Sm = S - 1
    pad = (-Sm) % seq_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
    n = (Sm + pad) // seq_chunk
    xs = jnp.moveaxis(x.reshape(B, n, seq_chunk, -1), 1, 0)
    ts = jnp.moveaxis(tgt.reshape(B, n, seq_chunk), 1, 0)
    valid = jnp.moveaxis(
        (jnp.arange(Sm + pad) < Sm).reshape(n, seq_chunk)[None].repeat(B, 0),
        1, 0)

    def chunk_nll(xc, tc, vc):
        logits = (nn.unembed(params["embed"], xc) if cfg.tie_embeddings
                  else nn.linear(params["lm_head"], xc, dtype=dt)
                  .astype(jnp.float32))
        logits = nn.softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        hit = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - hit) * vc)

    def body(acc, xs_):
        xc, tc, vc = xs_
        return acc + jax.checkpoint(chunk_nll)(xc, tc, vc), None

    total_nll, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (xs, ts, valid))
    loss = total_nll / (B * Sm)
    return loss + aux, {"ce_loss": loss, "aux_loss": aux,
                        "perplexity": jnp.exp(loss)}


def _trunk(params, cfg, batch, *, long_context=False):
    """forward() up to (but excluding) the unembedding; returns (x, aux)."""
    # reuse forward with a sentinel: final norm applied, no unembed
    return forward(params, cfg, batch, long_context=long_context,
                   return_hidden=True)
