"""jit'd public wrappers around the Pallas kernels.

These are the model-facing entry points: they handle head folding/GQA
layout, choose interpret mode automatically off-TPU (CPU validation per
the brief), and are shape-polymorphic over the model stacks' layouts.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import paged_prefill as _pp
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "interpret"))
def mha_flash_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        interpret: Optional[bool] = None):
    """Model-layout flash attention.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = G * KV (GQA).
    Returns (B, Sq, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    Bz, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    # fold (B, KV, G) -> BH; repeat kv per group via reshape-broadcast
    qf = q.reshape(Bz, Sq, KV, G, D).transpose(0, 2, 3, 1, 4) \
        .reshape(Bz * KV * G, Sq, D)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (Bz, KV, G, k.shape[1], D)).reshape(
                              Bz * KV * G, k.shape[1], D)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (Bz, KV, G, v.shape[1], D)).reshape(
                              Bz * KV * G, v.shape[1], D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              softcap=softcap, scale=scale,
                              interpret=interpret)
    return out.reshape(Bz, KV, G, Sq, D).transpose(0, 3, 1, 2, 4) \
        .reshape(Bz, Sq, H, D)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None):
    """Model-layout paged decode attention.

    q: (B, 1, H, D) — one decoding token per sequence, H = G * KV (GQA);
    k_pages, v_pages: (num_pages, page_size, KV, D) block storage;
    block_tables: (B, pages_per_seq) int32; lengths: (B,) valid positions
    per sequence including the current token.  Returns (B, 1, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, S, H, D = q.shape
    assert S == 1, "paged attention is single-token decode"
    KV = k_pages.shape[2]
    G = H // KV
    qf = q[:, 0].reshape(B, KV, G, D)
    out = _pa.paged_attention(qf, k_pages, v_pages, block_tables, lengths,
                              window=window, softcap=softcap, scale=scale,
                              interpret=interpret)
    return out.reshape(B, 1, H, D)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, block_tables, start_pos,
                            q_lens, *, window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            scale: Optional[float] = None,
                            interpret: Optional[bool] = None):
    """Model-layout paged chunked-prefill attention.

    q: (B, C, H, D) — a chunk of C query tokens per sequence, H = G * KV
    (GQA); k_pages, v_pages: (num_pages, page_size, KV, D) block storage
    with the chunk's own K/V already scattered in; block_tables:
    (B, pages_per_seq) int32; start_pos: (B,) absolute position of each
    row's first query token; q_lens: (B,) valid query tokens per row
    (rows/tokens past q_lens are padding and return zeros).
    Returns (B, C, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, C, H, D = q.shape
    KV = k_pages.shape[2]
    G = H // KV
    # fold (C, G) -> CG rows grouped per KV head: row c*G+g
    qf = q.reshape(B, C, KV, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KV, C * G, D)
    out = _pp.paged_prefill(qf, k_pages, v_pages, block_tables, start_pos,
                            q_lens, group=G, window=window, softcap=softcap,
                            scale=scale, interpret=interpret)
    return out.reshape(B, KV, C, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, C, H, D)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 256,
        interpret: Optional[bool] = None):
    """Model-layout SSD scan.

    x: (b, S, H, P); dt: (b, S, H); A: (H,); B, C: (b, S, G, N), G | H.
    Returns y: (b, S, H, P).
    """
    if interpret is None:
        interpret = _default_interpret()
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bf = jnp.repeat(B, rep, axis=2)                       # (b, S, H, N)
    Cf = jnp.repeat(C, rep, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(b * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(b * H, S)
    Bff = Bf.transpose(0, 2, 1, 3).reshape(b * H, S, N)
    Cff = Cf.transpose(0, 2, 1, 3).reshape(b * H, S, N)
    Af = jnp.broadcast_to(A[None], (b, H)).reshape(b * H)
    y = _ssd.ssd_scan(xf, dtf, Af, Bff, Cff, chunk, interpret=interpret)
    return y.reshape(b, H, S, P).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6,
            interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _rn.rmsnorm(x, scale, eps=eps, interpret=interpret)
