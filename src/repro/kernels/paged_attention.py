"""Pallas TPU paged-attention decode: gather K/V through block tables.

Single-token decode attention where K/V live in *block* (page) storage —
``(num_pages, page_size, KV, D)`` — instead of one dense contiguous
sequence axis per slot.  Each live sequence owns a per-slot row of a
``(B, pages_per_seq)`` block table naming the pages that back its token
positions in order; the pool hands pages out on demand, so the resident
KV footprint tracks the tokens actually generated, not the worst case.

TPU adaptation: the block table and per-sequence lengths ride in as
*scalar-prefetch* operands (``pltpu.PrefetchScalarGridSpec``), so the
page index feeding each K/V tile's DMA — ``table[b, i]`` — is known
before the kernel body runs.  The grid is ``(B, KV, pages_per_seq)``
with the page axis innermost and sequential, so the online-softmax state
``(m, l, acc)`` accumulates in VMEM scratch across pages exactly like
the flash-attention kernel accumulates across KV tiles.  Pages past a
sequence's length are skipped (their table entries point at the pool's
trash page and the position mask kills any stray values).

Features match the dense decode path: GQA (per-KV-head grid axis with
all G query heads of the group in one tile), sliding window, and
attention-logit softcap.  Validated against
``repro.kernels.ref.paged_attention_ref`` in interpret mode (CPU), which
is itself validated against a dense gather + softmax in the tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float,
                  window: Optional[int], softcap: Optional[float],
                  page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]                     # valid positions: [0, length)
    q_pos = length - 1                      # the one decoding token
    k_start = i * page_size

    # page-level reachability: skip pages holding no attended position
    reachable = k_start < length
    if window is not None:
        reachable &= k_start + page_size - 1 >= q_pos - (window - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length                            # causal: q is last
        if window is not None:
            mask &= (q_pos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (G,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == ni - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False):
    """Paged single-token decode attention.

    q: (B, KV, G, D) — one query token per sequence, grouped GQA layout;
    k_pages, v_pages: (num_pages, page_size, KV, D) block storage;
    block_tables: (B, pages_per_seq) int32 — page ids backing positions
      ``[j*page_size, (j+1)*page_size)`` of sequence b (entries past the
      sequence's extent may be any in-range id; they are masked);
    lengths: (B,) int32 — valid positions per sequence, **including** the
      current token (its K/V must already be written to its page).
    Returns (B, KV, G, D) in q.dtype.
    """
    B, KV, G, D = q.shape
    NP, page_size, KVp, Dp = k_pages.shape
    assert (KVp, Dp) == (KV, D), (k_pages.shape, q.shape)
    pages_per_seq = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    # garbage entries must still name a real page for the DMA
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, NP - 1)
    lengths = lengths.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, i, tbl, lens:
                         (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D), lambda b, h, i, tbl, lens:
                         (tbl[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D), lambda b, h, i, tbl, lens:
                         (tbl[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, i, tbl, lens:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),          # running max m
            pltpu.VMEM((G,), jnp.float32),          # running denom l
            pltpu.VMEM((G, D), jnp.float32),        # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          softcap=softcap, page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(tables, lengths, q, k_pages, v_pages)
