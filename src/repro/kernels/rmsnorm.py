"""Pallas TPU fused RMSNorm: normalize + (1+scale) gain in one HBM pass.

Memory-bound op — fusing the variance reduction with the scale multiply
removes an HBM round-trip of the activation tensor.  Rows are tiled
(block_rows, d) into VMEM; d stays whole per tile (lane-dim aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = int(np.prod(orig_shape[:-1]))
    xr = x.reshape(rows, d)
    br = min(block_rows, rows)
    rows_p = int(np.ceil(rows / br)) * br
    if rows_p != rows:
        xr = jnp.pad(xr, ((0, rows_p - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_p // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:rows].reshape(orig_shape)
