"""Pallas TPU flash attention: online-softmax tiling in VMEM.

Supports the features the assigned archs need: GQA (q-head -> kv-head via
index map), causal masking, sliding-window, and gemma2's attention-logit
softcap — all folded into the score tile inside the kernel.

TPU adaptation: the grid's last axis iterates KV blocks *sequentially* per
(batch*head, q-block), so the running (m, l, acc) online-softmax state
lives in VMEM scratch across grid steps — the TPU replacement for the GPU
version's per-SM shared-memory accumulators.  Block shapes default to
(128, 128): MXU-aligned in both the q and kv tile dims.

Validated against ``repro.kernels.ref.attention_ref`` in interpret mode
(tests/test_kernels.py sweeps shapes, dtypes, masks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level reachability: skip fully-masked tiles entirely
    reachable = jnp.bool_(True)
    if causal:
        reachable &= k_start <= q_start + block_q - 1
    if window is not None:
        # newest kv in this tile vs the oldest q row's window lower bound
        reachable &= k_start + block_k - 1 >= q_start - (window - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (bq, d)
        k = k_ref[0].astype(jnp.float32)                      # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        # rows with no valid key yet keep m=NEG_INF; guard the rescale
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _flash_kernel_int8kv(q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         causal: bool, window, softcap, block_q: int,
                         block_k: int, seq_k: int):
    """int8-KV variant: k/v tiles are dequantized IN VMEM after the HBM
    load (per-token scales), so decode attention reads half the HBM bytes
    — the kernel-level realization of the §Perf B3 int8 cache win."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    reachable = jnp.bool_(True)
    if causal:
        reachable &= k_start <= q_start + block_q - 1
    if window is not None:
        reachable &= k_start + block_k - 1 >= q_start - (window - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = (k_ref[0].astype(jnp.float32)
             * ks_ref[0].astype(jnp.float32)[:, None])
        v = (v_ref[0].astype(jnp.float32)
             * vs_ref[0].astype(jnp.float32)[:, None])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_int8kv(q, k8, k_scale, v8, v_scale, *,
                           causal: bool = True, window=None, softcap=None,
                           scale=None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (BH, Sq, D) f32/bf16; k8, v8: (BH, Skv, D) int8;
    k_scale, v_scale: (BH, Skv) per-token absmax scales."""
    BH, Sq, D = q.shape
    Skv = k8.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    Sq_p = int(np.ceil(Sq / bq)) * bq
    Skv_p = int(np.ceil(Skv / bk)) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        k8 = jnp.pad(k8, ((0, 0), (0, Skv_p - Skv), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, Skv_p - Skv), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, Skv_p - Skv)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, Skv_p - Skv)))

    grid = (BH, Sq_p // bq, Skv_p // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel_int8kv, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=bq,
                          block_k=bk, seq_k=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k8, k_scale, v8, v_scale)
    return out[:, :Sq]


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (BH, Sq, D); k, v: (BH, Skv, D) — heads pre-folded into the batch
    dim (GQA: repeat kv refs via the caller's index fold, see ops.py).
    Returns (BH, Sq, D) in q.dtype.
    """
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    # pad to block multiples (masked out inside the kernel)
    Sq_p = int(np.ceil(Sq / bq)) * bq
    Skv_p = int(np.ceil(Skv / bk)) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0)))

    grid = (BH, Sq_p // bq, Skv_p // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=bq,
                          block_k=bk, seq_k=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max m
            pltpu.VMEM((bq,), jnp.float32),        # running denom l
            pltpu.VMEM((bq, D), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
