"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """q: (BH, Sq, D); k, v: (BH, Skv, D).  Naive softmax attention."""
    Sq, Skv, D = q.shape[1], k.shape[1], q.shape[2]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zeros (kernel semantics)
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD oracle — delegates to the model-level reference, which is
    itself validated against the naive recurrence in tests/test_ssm.py."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk, initial_state=initial_state)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)
