"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """q: (BH, Sq, D); k, v: (BH, Skv, D).  Naive softmax attention."""
    Sq, Skv, D = q.shape[1], k.shape[1], q.shape[2]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zeros (kernel semantics)
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale: Optional[float] = None,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """Oracle for the paged decode kernel: gather every sequence's pages
    back into a dense (B, T, KV, D) layout, then run naive masked softmax
    attention for the single query token.

    q: (B, KV, G, D); k_pages, v_pages: (num_pages, page_size, KV, D);
    block_tables: (B, pages_per_seq) int32; lengths: (B,) int32 counting
    valid positions including the current token.  Returns (B, KV, G, D).
    """
    B, KV, G, D = q.shape
    NP, page_size = k_pages.shape[0], k_pages.shape[1]
    pages_per_seq = block_tables.shape[1]
    T = pages_per_seq * page_size
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, NP - 1)
    k = k_pages[tables.reshape(-1)].reshape(B, T, KV, D)
    v = v_pages[tables.reshape(-1)].reshape(B, T, KV, D)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(T)[None, :]                        # (1, T)
    lengths = lengths.astype(jnp.int32)[:, None]
    mask = kpos < lengths                                # causal: q is last
    if window is not None:
        mask &= (lengths - 1) - kpos < window
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    any_valid = mask.any(axis=1)[:, None, None, None]
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def paged_prefill_ref(q, k_pages, v_pages, block_tables, start_pos, q_lens,
                      *, scale: Optional[float] = None,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None):
    """Oracle for the paged chunked-prefill kernel: gather every row's
    pages back into a dense (B, T, KV, D) layout, then run naive masked
    softmax attention for the whole query chunk.

    q: (B, C, KV, G, D) — chunk of query tokens per row, GQA-grouped;
    k_pages, v_pages: (num_pages, page_size, KV, D) block storage holding
    the chunk's own K/V at its absolute positions; block_tables:
    (B, pages_per_seq) int32; start_pos: (B,) absolute position of each
    row's first query; q_lens: (B,) valid query tokens per row (padding
    rows/tokens return zeros).  Returns (B, C, KV, G, D).
    """
    B, C, KV, G, D = q.shape
    NP, page_size = k_pages.shape[0], k_pages.shape[1]
    pages_per_seq = block_tables.shape[1]
    T = pages_per_seq * page_size
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, NP - 1)
    k = k_pages[tables.reshape(-1)].reshape(B, T, KV, D)
    v = v_pages[tables.reshape(-1)].reshape(B, T, KV, D)
    s = jnp.einsum("bckgd,btkd->bkgct", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = (start_pos.astype(jnp.int32)[:, None]
            + jnp.arange(C)[None, :])                    # (B, C)
    kpos = jnp.arange(T)[None, None, :]                  # (1, 1, T)
    mask = kpos <= qpos[:, :, None]                      # causal
    mask &= (jnp.arange(C)[None, :]
             < q_lens.astype(jnp.int32)[:, None])[:, :, None]
    if window is not None:
        mask &= (qpos[:, :, None] - kpos) < window
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgct,btkd->bckgd", p, v.astype(jnp.float32))
    any_valid = mask.any(axis=2)[:, :, None, None, None]
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD oracle — delegates to the model-level reference, which is
    itself validated against the naive recurrence in tests/test_ssm.py."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk, initial_state=initial_state)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)
