"""Pallas TPU paged chunked-prefill attention: query tiles over block tables.

The prefill counterpart of ``paged_attention.py``: causal attention for a
``(batch, chunk)`` tile of query tokens whose K/V history — including the
chunk itself — lives in *block* (page) storage ``(num_pages, page_size,
KV, D)``.  Each row of the batch is one sequence mid-prefill: its queries
sit at absolute positions ``[start[b], start[b] + q_len[b])`` and attend
every earlier position of the same sequence through the row's block
table.  This is what lets the serving engine write prefill KV straight
into pool blocks and never allocate the transient dense ``max_seq_len``
stripe the chunked-prefill path used to fill before scattering.

TPU adaptation, mirroring the decode kernel: the block table and the
per-row ``(start, q_len)`` scalars ride in as *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), so the page id feeding each K/V
tile's DMA — ``table[b, i]`` — is known before the kernel body runs.
The grid is ``(B, KV, pages_per_seq)`` with the page axis innermost and
sequential; the online-softmax state ``(m, l, acc)`` accumulates in VMEM
scratch across pages.  The query tile folds ``(chunk, G)`` into one
``CG = chunk * G`` axis (row ``c * G + g``), so GQA costs one page DMA
per KV head per page, never per query head; the per-row chunk index is
recovered in-kernel as ``row // G`` for the causal mask.

Pages holding no attended position — entirely past the newest query, or
entirely outside the sliding window of the *oldest* query in the tile —
are skipped at page granularity, so rows that are pure padding
(``q_len == 0``, co-admission waves shorter than the compiled batch)
cost zero compute.  Features match the decode kernel: GQA, sliding
window, attention-logit softcap.  Validated against
``repro.kernels.ref.paged_prefill_ref`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_prefill_kernel(tbl_ref, start_ref, qlen_ref, q_ref, k_ref, v_ref,
                          o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                          window: Optional[int], softcap: Optional[float],
                          page_size: int, group: int):
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[b]                    # first query's absolute position
    q_len = qlen_ref[b]                     # valid query rows in this chunk
    k_start = i * page_size

    # page-level reachability: the newest query bounds the causal extent,
    # the oldest query's window lower bound cuts pages that scrolled out
    reachable = (q_len > 0) & (k_start <= start + q_len - 1)
    if window is not None:
        reachable &= k_start + page_size - 1 >= start - (window - 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (CG, D)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # row c*G+g is query token c of the chunk (all G heads of a group
        # share one causal row)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        qpos = start + qi
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos <= qpos) & (qi < q_len)
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (CG,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # a fully-masked row (padding query) has m_new == NEG_INF; its
        # probabilities must be 0, not exp(NEG_INF - NEG_INF) = 1
        p = jnp.where(m_new[:, None] == NEG_INF, 0.0,
                      jnp.exp(s - m_new[:, None]))
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == ni - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def paged_prefill(q, k_pages, v_pages, block_tables, start_pos, q_lens, *,
                  group: int, scale: Optional[float] = None,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  interpret: bool = False):
    """Paged chunked-prefill attention (grouped, chunk-folded layout).

    q: (B, KV, CG, D) — CG = chunk * group, row ``c * group + g`` is
      query token c of the chunk for head g of the KV group;
    k_pages, v_pages: (num_pages, page_size, KV, D) block storage, with
      the chunk's own K/V already written at positions
      ``[start_pos[b], start_pos[b] + q_lens[b])``;
    block_tables: (B, pages_per_seq) int32 — page ids backing positions
      ``[j*page_size, (j+1)*page_size)`` of sequence b (entries past the
      sequence's extent may be any id; they are clamped and masked);
    start_pos: (B,) int32 — absolute position of each row's first query;
    q_lens: (B,) int32 — valid query tokens per row (0 = padding row,
      fully skipped).
    Returns (B, KV, CG, D) in q.dtype; padding query rows are zeros.
    """
    B, KV, CG, D = q.shape
    NP, page_size, KVp, Dp = k_pages.shape
    assert (KVp, Dp) == (KV, D), (k_pages.shape, q.shape)
    assert CG % group == 0, (CG, group)
    pages_per_seq = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    # garbage entries must still name a real page for the DMA
    tables = jnp.clip(block_tables.astype(jnp.int32), 0, NP - 1)
    start_pos = start_pos.astype(jnp.int32)
    q_lens = q_lens.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, CG, D), lambda b, h, i, tbl, st, ql:
                         (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D), lambda b, h, i, tbl, st, ql:
                         (tbl[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D), lambda b, h, i, tbl, st, ql:
                         (tbl[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, CG, D), lambda b, h, i, tbl, st, ql:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((CG,), jnp.float32),         # running max m
            pltpu.VMEM((CG,), jnp.float32),         # running denom l
            pltpu.VMEM((CG, D), jnp.float32),       # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_prefill_kernel, scale=scale, window=window,
                          softcap=softcap, page_size=page_size, group=group),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, CG, D), q.dtype),
        interpret=interpret,
    )(tables, start_pos, q_lens, q, k_pages, v_pages)
