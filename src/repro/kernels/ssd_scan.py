"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the GPU reference
parallelizes the inter-chunk recurrence with a warp-level scan; on TPU the
grid's trailing axis executes *sequentially*, so the (N, P) inter-chunk
state lives in a VMEM scratch accumulator carried across chunk steps, and
each chunk step is three MXU matmuls (C·Bᵀ score tile, M·x intra-chunk
output, state-weighted Bᵀ·x update) over an (L=chunk)-aligned tile —
exactly the structure of ``repro.models.ssm.ssd_chunked``, which is the
oracle this kernel is validated against.

Grid: (batch*heads, num_chunks); per-(bh) state resets at chunk 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0, 0]                                        # per-head decay rate
    x = x_ref[0, 0].astype(jnp.float32)                    # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                  # (L,)
    B = b_ref[0, 0].astype(jnp.float32)                    # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)                    # (L, N)

    da = dt * a                                            # (L,) log-decays
    cum = jnp.cumsum(da)                                   # inclusive
    seg = cum[-1]

    # ---- intra-chunk: masked attention-like matmul (MXU) -------------------
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    gates = jnp.where(li >= lj, decay, 0.0)
    M = scores * gates * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- inter-chunk: contribution of the carried state ----------------------
    state_in = state_ref[...]                              # (N, P)
    Cg = C * jnp.exp(cum)[:, None]
    y_inter = jax.lax.dot_general(Cg, state_in, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- state update ----------------------------------------------------------
    w = jnp.exp(seg - cum) * dt                            # (L,)
    Bw = B * w[:, None]                                    # (L, N)
    new_contrib = jax.lax.dot_general(Bw, x, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(seg) * state_in + new_contrib

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, chunk: int, interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B, C: (BH, S, N).

    Heads are pre-folded into the leading dim (GQA-style groups repeated by
    the caller — see ops.py).  Returns y: (BH, S, P) in x.dtype.
    """
    BH, S, P = x.shape
    N = B.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xr = x.reshape(BH, nc, L, P)
    dtr = dt.reshape(BH, nc, L)
    Br = B.reshape(BH, nc, L, N)
    Cr = C.reshape(BH, nc, L, N)
    Ar = A.reshape(BH, 1)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=L),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),            # A
            pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),  # x
            pl.BlockSpec((1, 1, L), lambda b, c: (b, c, 0)),      # dt
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),  # B
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),  # C
        ],
        out_specs=pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(Ar, xr, dtr, Br, Cr)
    return y.reshape(BH, S, P)
