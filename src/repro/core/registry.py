"""Offline package registry + dependency resolver.

This models §II-A of the paper: a secure HPC system has *no internet
access*, so ``pip install`` on the cluster cannot work, and a single shared
Python instance breaks under multi-framework use because transitive
dependency up/downgrades clobber previously installed frameworks (the
paper's TensorFlow-then-Caffe example).

The registry is a local, versioned index.  ``Resolver`` performs constraint
resolution at *image build time* — the Charliecloud answer: every
environment is resolved against the offline index into an immutable,
per-image package set, so two frameworks with conflicting pins live in two
images instead of fighting over one site-packages.

``SharedEnvironment`` deliberately reproduces the breakage: sequential
installs mutate one shared package set, and the conflict test in
``tests/test_registry.py`` shows framework A's pins violated after
installing framework B — then shows two ``EnvironmentCapsule`` images
resolving cleanly.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class ResolutionError(RuntimeError):
    pass


class OfflineViolation(RuntimeError):
    """Raised when something tries to reach the network on the cluster."""


# ---------------------------------------------------------------------------
# Versions & constraints (PEP-440-lite: major.minor.patch, ==, >=, <=, <, >, !=)
# ---------------------------------------------------------------------------

def parse_version(v: str) -> Tuple[int, ...]:
    parts = v.split(".")
    if not all(p.isdigit() for p in parts):
        raise ValueError(f"bad version {v!r}")
    return tuple(int(p) for p in parts) + (0,) * (3 - len(parts))


_CONSTRAINT_RE = re.compile(r"^(==|>=|<=|!=|<|>)?\s*([\d.]+)$")


@dataclass(frozen=True)
class Constraint:
    op: str
    version: Tuple[int, ...]

    @classmethod
    def parse(cls, s: str) -> "Constraint":
        m = _CONSTRAINT_RE.match(s.strip())
        if not m:
            raise ValueError(f"bad constraint {s!r}")
        return cls(m.group(1) or "==", parse_version(m.group(2)))

    def satisfied_by(self, v: Tuple[int, ...]) -> bool:
        return {"==": v == self.version, "!=": v != self.version,
                ">=": v >= self.version, "<=": v <= self.version,
                ">": v > self.version, "<": v < self.version}[self.op]

    def __str__(self) -> str:
        return f"{self.op}{'.'.join(map(str, self.version))}"


@dataclass(frozen=True)
class Requirement:
    name: str
    constraints: Tuple[Constraint, ...] = ()

    @classmethod
    def parse(cls, s: str) -> "Requirement":
        m = re.match(r"^([A-Za-z0-9_.-]+)\s*(.*)$", s.strip())
        name, rest = m.group(1), m.group(2)
        cons = tuple(Constraint.parse(c) for c in rest.split(",") if c.strip())
        return cls(name.lower(), cons)

    def satisfied_by(self, v: Tuple[int, ...]) -> bool:
        return all(c.satisfied_by(v) for c in self.constraints)

    def __str__(self) -> str:
        return self.name + ",".join(map(str, self.constraints))


@dataclass(frozen=True)
class PackageSpec:
    name: str
    version: str
    requires: Tuple[str, ...] = ()          # requirement strings

    @property
    def vtuple(self) -> Tuple[int, ...]:
        return parse_version(self.version)

    @property
    def requirements(self) -> Tuple[Requirement, ...]:
        return tuple(Requirement.parse(r) for r in self.requires)


# ---------------------------------------------------------------------------
# The offline index
# ---------------------------------------------------------------------------

class PackageIndex:
    """A local (air-gap-safe) package index."""

    def __init__(self, offline: bool = True):
        self._pkgs: Dict[str, Dict[str, PackageSpec]] = {}
        self.offline = offline

    def publish(self, spec: PackageSpec) -> None:
        self._pkgs.setdefault(spec.name.lower(), {})[spec.version] = spec

    def versions(self, name: str) -> List[PackageSpec]:
        out = sorted(self._pkgs.get(name.lower(), {}).values(),
                     key=lambda s: s.vtuple, reverse=True)
        return out

    def fetch_remote(self, name: str) -> PackageSpec:
        raise OfflineViolation(
            f"attempted network fetch of {name!r}: the cluster has no internet "
            "access (paper §III-A); resolve at image build time instead")


def default_index() -> PackageIndex:
    """An index stocked with the paper's cast of characters.

    The tensorflow/caffe pins reproduce the paper's §II-A conflict:
    tensorflow 1.11 needs protobuf>=3.6, caffe 1.0 pins protobuf==2.6.1.
    """
    idx = PackageIndex()
    for spec in [
        PackageSpec("numpy", "1.15.4"),
        PackageSpec("numpy", "1.14.5"),
        PackageSpec("protobuf", "3.6.1"),
        PackageSpec("protobuf", "3.6.0"),
        PackageSpec("protobuf", "2.6.1"),
        PackageSpec("six", "1.11.0"),
        PackageSpec("tensorflow", "1.11.0",
                    ("numpy>=1.14.5", "protobuf>=3.6.0", "six>=1.10.0")),
        PackageSpec("caffe", "1.0.0", ("numpy>=1.14.0", "protobuf==2.6.1")),
        PackageSpec("keras", "2.2.4", ("numpy>=1.14.5", "six>=1.9.0")),
        PackageSpec("horovod", "0.15.2", ("tensorflow>=1.10.0", "six>=1.10.0")),
        PackageSpec("intel-tensorflow", "1.11.0",
                    ("numpy>=1.14.5", "protobuf>=3.6.0", "six>=1.10.0")),
        PackageSpec("mpi4py", "3.0.0"),
        PackageSpec("jax-repro", "0.1.0", ("numpy>=1.14.5",)),
    ]:
        idx.publish(spec)
    return idx


# ---------------------------------------------------------------------------
# Resolver (build-time, per-image)
# ---------------------------------------------------------------------------

class Resolver:
    """Backtracking version resolver over the offline index."""

    def __init__(self, index: PackageIndex):
        self.index = index

    def resolve(self, requirements: Sequence[str]) -> Dict[str, PackageSpec]:
        reqs = [Requirement.parse(r) for r in requirements]
        solution = self._solve({}, list(reqs))
        if solution is None:
            raise ResolutionError(
                f"no consistent package set satisfies {list(map(str, reqs))}")
        return solution

    def _solve(self, pinned: Dict[str, PackageSpec],
               todo: List[Requirement]) -> Optional[Dict[str, PackageSpec]]:
        if not todo:
            return dict(pinned)
        req, rest = todo[0], todo[1:]
        if req.name in pinned:
            if req.satisfied_by(pinned[req.name].vtuple):
                return self._solve(pinned, rest)
            return None                                   # conflict: backtrack
        candidates = [s for s in self.index.versions(req.name)
                      if req.satisfied_by(s.vtuple)]
        if not candidates and not self.index._pkgs.get(req.name):
            # the paper's failure mode: pip would now hit the network
            self.index.fetch_remote(req.name)
        for cand in candidates:
            pinned[req.name] = cand
            sol = self._solve(pinned, rest + list(cand.requirements))
            if sol is not None:
                return sol
            del pinned[req.name]
        return None


# ---------------------------------------------------------------------------
# The shared-environment failure mode (§II-A) — kept as an executable model
# ---------------------------------------------------------------------------

class SharedEnvironment:
    """A single shared Python instance: sequential ``pip install`` semantics.

    Installing framework B silently up/downgrades shared dependencies that
    framework A pinned — ``check()`` then reports A as broken.  This is the
    behavior the paper cites as the reason a shared Python cannot serve
    multi-user HPC, and the motivation for per-image resolution.
    """

    def __init__(self, index: PackageIndex):
        self.index = index
        self.installed: Dict[str, PackageSpec] = {}
        self.roots: List[str] = []

    def pip_install(self, requirement: str) -> None:
        req = Requirement.parse(requirement)
        resolver = Resolver(self.index)
        # pip-style: resolve the new root in isolation, then overwrite shared
        # packages with whatever the new resolution picked.
        sol = resolver.resolve([requirement])
        self.installed.update(sol)
        self.roots.append(requirement)

    def check(self) -> Dict[str, List[str]]:
        """Return {root: [violations]} across everything installed."""
        problems: Dict[str, List[str]] = {}
        for root in self.roots:
            name = Requirement.parse(root).name
            spec = self.installed.get(name)
            stack = list(spec.requirements) if spec else []
            seen = set()
            while stack:
                r = stack.pop()
                if r.name in seen:
                    continue
                seen.add(r.name)
                dep = self.installed.get(r.name)
                if dep is None:
                    problems.setdefault(root, []).append(f"missing {r.name}")
                elif not r.satisfied_by(dep.vtuple):
                    problems.setdefault(root, []).append(
                        f"{r} violated by installed {dep.name}=={dep.version}")
                else:
                    stack.extend(dep.requirements)
        return problems
