"""Charliecloud-style environment capsules (UDSS) — the paper's §II-F/§III-B.

The workflow contract implemented here is exactly the paper's:

  workstation (has internet, has root):
      ch-build            -> ImageBuilder.build()      (resolve deps, §II-A)
      ch-docker2tar       -> Image.flatten()           (single archive file)
      scp                 -> transfer()                (onto the cluster)
  cluster (no internet, no root, Slurm only):
      ch-tar2dir          -> unpack()                  (into node-local tmpfs)
      ch-run              -> CapsuleRuntime.run()      (unprivileged launch)

Python cannot create kernel user namespaces, so the *isolation mechanism*
is simulated — but the *policy* is real and enforced: images are immutable
(content-hash verified before and after every run), the runtime scrubs the
environment and blocks network access flags, building requires the
"workstation" context (network+root) while running requires neither, and
attempts to install packages inside a running capsule raise
``OfflineViolation`` just like ``pip install`` dies on the real SuperMUC-NG.
The security review table of the paper (Docker: root escalation; Singularity:
banned at LRZ after privilege escalation) is encoded in ``SecurityPolicy``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import inspect
import io
import json
import os
import shutil
import tarfile
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.registry import (OfflineViolation, PackageIndex, PackageSpec,
                                 Resolver)


class SecurityError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Security policy (the paper's §II-C..F comparison, encoded)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RuntimeProfile:
    name: str
    requires_root_daemon: bool
    requires_setuid: bool
    uses_cgroups: bool                 # conflicts with Slurm's cgroup usage
    unprivileged_user_namespace: bool
    known_escalations: bool


RUNTIME_PROFILES = {
    "docker": RuntimeProfile("docker", True, False, True, False, True),
    "singularity": RuntimeProfile("singularity", False, True, False, True, True),
    "shifter": RuntimeProfile("shifter", False, False, False, False, False),
    "charliecloud": RuntimeProfile("charliecloud", False, False, False, True, False),
}


@dataclass(frozen=True)
class SecurityPolicy:
    """LRZ-style site policy for a secure HPC system."""
    allow_internet: bool = False
    allow_root: bool = False
    allow_setuid: bool = False
    allow_cgroup_runtimes: bool = False     # Slurm owns cgroups
    allow_known_escalations: bool = False   # the Singularity incident

    def admit(self, profile: RuntimeProfile) -> None:
        if profile.requires_root_daemon and not self.allow_root:
            raise SecurityError(
                f"{profile.name}: requires a root daemon (paper §II-C)")
        if profile.requires_setuid and not self.allow_setuid:
            raise SecurityError(
                f"{profile.name}: setuid binary not allowed on this site")
        if profile.uses_cgroups and not self.allow_cgroup_runtimes:
            raise SecurityError(
                f"{profile.name}: cgroup isolation conflicts with Slurm")
        if profile.known_escalations and not self.allow_known_escalations:
            raise SecurityError(
                f"{profile.name}: banned after privilege-escalation incident "
                "(paper §II-D)")
        if not profile.unprivileged_user_namespace:
            raise SecurityError(
                f"{profile.name}: needs admin setup; site requires "
                "user-namespace-only launch (paper §II-E/F)")


# ---------------------------------------------------------------------------
# Execution contexts
# ---------------------------------------------------------------------------

@dataclass
class HostContext:
    """Where a command runs: the connected workstation or the secure cluster."""
    name: str
    has_internet: bool
    has_root: bool

    def require_internet(self, what: str) -> None:
        if not self.has_internet:
            raise OfflineViolation(
                f"{what} needs internet but {self.name} is air-gapped")


WORKSTATION = HostContext("workstation", has_internet=True, has_root=True)
CLUSTER = HostContext("supermuc-ng", has_internet=False, has_root=False)


# ---------------------------------------------------------------------------
# Image definition & build
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ImageDefinition:
    """The Dockerfile analogue."""
    name: str
    base: str = "ubuntu:18.04"
    requirements: Sequence[str] = ()         # resolved at build time
    env: Dict[str, str] = field(default_factory=dict)
    entrypoint: str = "python"
    labels: Dict[str, str] = field(default_factory=dict)


@dataclass(frozen=True)
class Image:
    """A built, immutable image: resolved package set + content hash."""
    definition: ImageDefinition
    packages: Dict[str, str]                 # name -> version (fully resolved)
    content_hash: str
    built_at: float

    def manifest(self) -> Dict[str, Any]:
        return {
            "name": self.definition.name,
            "base": self.definition.base,
            "packages": dict(sorted(self.packages.items())),
            "env": dict(self.definition.env),
            "entrypoint": self.definition.entrypoint,
            "labels": dict(self.definition.labels),
            "content_hash": self.content_hash,
        }


class ImageBuilder:
    """``ch-build``: runs on the workstation, resolves deps against the index."""

    def __init__(self, index: PackageIndex, context: HostContext = WORKSTATION):
        self.index = index
        self.context = context

    def build(self, definition: ImageDefinition) -> Image:
        # dependency resolution may need the index network mirror — the
        # whole point is that this happens HERE, not on the cluster.
        self.context.require_internet(f"building image {definition.name!r}")
        solution = Resolver(self.index).resolve(list(definition.requirements))
        packages = {s.name: s.version for s in solution.values()}
        blob = json.dumps({"def": dataclasses.asdict(definition),
                           "pkgs": sorted(packages.items())},
                          sort_keys=True, default=list).encode()
        return Image(definition, packages,
                     hashlib.sha256(blob).hexdigest(), time.time())


# ---------------------------------------------------------------------------
# Flatten / transfer / unpack (ch-docker2tar, scp, ch-tar2dir)
# ---------------------------------------------------------------------------

def flatten(image: Image, out_dir: Path) -> Path:
    """``ch-docker2tar``: one archive file, the unit of distribution."""
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{image.definition.name}.tar.gz"
    buf = io.BytesIO()
    with tarfile.open(fileobj=buf, mode="w:gz") as tar:
        data = json.dumps(image.manifest(), indent=2).encode()
        info = tarfile.TarInfo("image/manifest.json")
        info.size = len(data)
        tar.addfile(info, io.BytesIO(data))
        for pkg, ver in sorted(image.packages.items()):
            pdata = f"# site-packages stand-in for {pkg}=={ver}\n".encode()
            pinfo = tarfile.TarInfo(f"image/site-packages/{pkg}-{ver}/__init__.py")
            pinfo.size = len(pdata)
            tar.addfile(pinfo, io.BytesIO(pdata))
    path.write_bytes(buf.getvalue())
    return path


def transfer(archive: Path, cluster_dir: Path) -> Path:
    """``scp`` to the cluster: the only thing that crosses the air gap."""
    cluster_dir = Path(cluster_dir)
    cluster_dir.mkdir(parents=True, exist_ok=True)
    dest = cluster_dir / Path(archive).name
    shutil.copy2(archive, dest)
    return dest


def unpack(archive: Path, dest_root: Path,
           context: HostContext = CLUSTER) -> Path:
    """``ch-tar2dir``: unpack into node-local storage (tmpfs stand-in).

    Refuses to clobber an existing unpacked image of a different build —
    the paper's warning about ch-tar2dir overwriting same-named dirs.
    """
    dest_root = Path(dest_root)
    name = Path(archive).name.replace(".tar.gz", "")
    dest = dest_root / name
    with tarfile.open(archive, "r:gz") as tar:
        manifest = json.loads(tar.extractfile("image/manifest.json").read())
        if dest.exists():
            # a crashed prior ch-tar2dir leaves a partial tree: a missing
            # or unparseable manifest is indistinguishable from a foreign
            # image, so it gets the same refusal instead of a raw
            # FileNotFoundError / JSONDecodeError
            try:
                old = json.loads((dest / "image/manifest.json").read_text())
                old_hash = old["content_hash"]
            except (OSError, ValueError, KeyError):
                old_hash = None
            if old_hash != manifest["content_hash"]:
                raise SecurityError(
                    f"{dest} holds a different or partially unpacked image "
                    "(hash mismatch); refusing to overwrite — remove it "
                    "explicitly first")
            shutil.rmtree(dest)
        dest.mkdir(parents=True)
        tar.extractall(dest, filter="data")
    return dest


def _tree_hash(root: Path) -> str:
    h = hashlib.sha256()
    for p in sorted(Path(root).rglob("*")):
        if p.is_file():
            h.update(p.relative_to(root).as_posix().encode())
            h.update(p.read_bytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# ch-run: the unprivileged runtime
# ---------------------------------------------------------------------------

# env vars that leak host identity / enable network — scrubbed on entry
_SCRUBBED = ("LD_PRELOAD", "LD_LIBRARY_PATH", "PYTHONPATH_HOST",
             "http_proxy", "https_proxy", "HTTP_PROXY", "HTTPS_PROXY",
             "SSH_AUTH_SOCK")

# Live capsule frames, in entry order.  The old save/clear/restore of the
# whole process environment corrupted it as soon as two capsule runs
# interleaved (A enters, B enters, A's exit restores a snapshot that
# resurrects B's scrubbed vars and drops B's capsule vars).  Instead each
# run owns a composed per-run env *frame*; os.environ is rebuilt from the
# host baseline plus the live frames on every entry/exit, so any exit
# order converges and the last exit restores the host env exactly.
_ACTIVE_FRAMES: List[Dict[str, str]] = []
_HOST_BASELINE: Optional[Dict[str, str]] = None


def _apply_frames() -> None:
    global _HOST_BASELINE
    if _HOST_BASELINE is None:
        return
    merged = dict(_HOST_BASELINE)
    if _ACTIVE_FRAMES:
        for k in _SCRUBBED:
            merged.pop(k, None)
        for frame in _ACTIVE_FRAMES:
            merged.update(frame)
    os.environ.clear()
    os.environ.update(merged)
    if not _ACTIVE_FRAMES:
        _HOST_BASELINE = None


def _accepts_capsule_env(fn: Callable[..., Any]) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    return "capsule_env" in params


@dataclass
class RunResult:
    value: Any
    image: str
    rank: int
    world_size: int
    uid_map: str
    env: Dict[str, str]
    wall_time_s: float


class CapsuleRuntime:
    """``ch-run`` analogue: launch user code inside an unpacked image.

    * verifies the image tree hash before AND after the run (immutability —
      a writeable-image run must opt in like ch-run's ``-w``);
    * scrubs the environment and injects the image's env;
    * simulates the user-namespace uid map (host uid -> container uid 0
      mapping without privilege, paper §II-B);
    * exposes rank/world_size the way Slurm+MPI would.
    """

    def __init__(self, policy: Optional[SecurityPolicy] = None,
                 context: HostContext = CLUSTER):
        self.policy = policy or SecurityPolicy()
        self.policy.admit(RUNTIME_PROFILES["charliecloud"])
        self.context = context

    @staticmethod
    def compose_env(image_dir: Path, manifest: Dict[str, Any],
                    extra_env: Optional[Dict[str, str]] = None
                    ) -> Dict[str, str]:
        """The per-run capsule environment as a plain dict — what ch-run
        would hand the contained process."""
        env = {"REPRO_CAPSULE": manifest["name"],
               "REPRO_CAPSULE_ROOT": str(image_dir),
               "REPRO_NO_NETWORK": "1"}
        env.update(manifest.get("env", {}))
        env.update(extra_env or {})
        return env

    @contextlib.contextmanager
    def _capsule_env(self, image_dir: Path, manifest: Dict[str, Any],
                     extra_env: Optional[Dict[str, str]]):
        global _HOST_BASELINE
        frame = self.compose_env(image_dir, manifest, extra_env)
        if not _ACTIVE_FRAMES:
            _HOST_BASELINE = dict(os.environ)
        _ACTIVE_FRAMES.append(frame)
        _apply_frames()
        try:
            yield frame
        finally:
            _ACTIVE_FRAMES.remove(frame)
            _apply_frames()

    def run(self, image_dir: Path, fn: Callable[..., Any], *args,
            rank: int = 0, world_size: int = 1,
            env: Optional[Dict[str, str]] = None,
            writeable: bool = False, **kwargs) -> RunResult:
        image_dir = Path(image_dir)
        manifest = json.loads((image_dir / "image/manifest.json").read_text())
        pre = _tree_hash(image_dir)
        uid = os.getuid() if hasattr(os, "getuid") else 1000
        t0 = time.perf_counter()
        with self._capsule_env(image_dir, manifest, env) as frame:
            # the composed env is the authoritative per-run scope:
            # functions that declare a ``capsule_env`` parameter receive
            # it directly and stay correct even when another in-process
            # capsule is live concurrently (os.environ then holds the
            # union, last entrant winning on shared keys)
            if _accepts_capsule_env(fn):
                kwargs = {**kwargs, "capsule_env": frame}
            value = fn(*args, **kwargs)
        wall = time.perf_counter() - t0
        if not writeable and _tree_hash(image_dir) != pre:
            raise SecurityError(
                "image tree modified during run without -w (immutability "
                "violation)")
        return RunResult(value, manifest["name"], rank, world_size,
                         uid_map=f"{uid}->0 (user namespace)",
                         env=dict(manifest.get("env", {})),
                         wall_time_s=wall)


def capsule_pip_install(package: str) -> None:
    """What happens if user code tries to install packages inside a capsule
    on the cluster — the paper: "pip install will not succeed"."""
    if os.environ.get("REPRO_NO_NETWORK") == "1":
        raise OfflineViolation(
            f"pip install {package}: no route to pypi.org from the secure "
            "cluster; bake the dependency into the image at build time")
    raise RuntimeError("capsule_pip_install called outside a capsule")
