"""The paper's primary contribution: secure container deployment of AI
frameworks on air-gapped HPC (Charliecloud-style capsules) + Horovod-style
allreduce data parallelism, in JAX."""
from repro.core import container, deploy, hvd, paramserver, registry  # noqa: F401
