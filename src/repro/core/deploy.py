"""End-to-end deployment pipeline — the paper's §III-B/§IV workflow as code.

``DeploymentPipeline.deploy()`` executes the full Charliecloud sequence:

    build (workstation) -> flatten -> transfer -> unpack (cluster)
    -> generate the Slurm submission script -> launch via CapsuleRuntime

and returns a ``Deployment`` handle whose ``run()`` executes user entrypoints
inside the capsule with Slurm/MPI-style rank env.  This is the object the
examples and the container-overhead benchmark drive.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import container as cc
from repro.core.registry import PackageIndex, default_index


@dataclass
class Deployment:
    image: cc.Image
    archive: Path
    unpacked: Path
    runtime: cc.CapsuleRuntime
    slurm_script: str
    log: List[str] = field(default_factory=list)

    def run(self, fn: Callable[..., Any], *args, ranks: int = 1,
            env: Optional[Dict[str, str]] = None, **kwargs) -> List[cc.RunResult]:
        """Launch ``fn`` on ``ranks`` ranks (sequentially here — one host),
        each inside the capsule with its Slurm/MPI rank env."""
        results = []
        for r in range(ranks):
            rank_env = dict(env or {})
            rank_env.update({
                "SLURM_PROCID": str(r), "SLURM_NTASKS": str(ranks),
                "OMPI_COMM_WORLD_RANK": str(r),
                "OMPI_COMM_WORLD_SIZE": str(ranks),
            })
            results.append(self.runtime.run(
                self.unpacked, fn, *args, rank=r, world_size=ranks,
                env=rank_env, **kwargs))
        return results


class DeploymentPipeline:
    def __init__(self, index: Optional[PackageIndex] = None,
                 policy: Optional[cc.SecurityPolicy] = None,
                 workstation: cc.HostContext = cc.WORKSTATION,
                 cluster: cc.HostContext = cc.CLUSTER):
        self.index = index or default_index()
        self.policy = policy or cc.SecurityPolicy()
        self.workstation = workstation
        self.cluster = cluster

    def deploy(self, definition: cc.ImageDefinition, work_dir: Path,
               nodes: int = 1, ranks_per_node: int = 1,
               threads_per_rank: int = 96) -> Deployment:
        work_dir = Path(work_dir)
        log = []
        # 1. ch-build on the workstation (deps resolved against the index)
        builder = cc.ImageBuilder(self.index, self.workstation)
        image = builder.build(definition)
        log.append(f"ch-build: {image.definition.name} "
                   f"({len(image.packages)} packages, {image.content_hash[:12]})")
        # 2. ch-docker2tar
        archive = cc.flatten(image, work_dir / "workstation")
        log.append(f"ch-docker2tar: {archive.name} "
                   f"({archive.stat().st_size} bytes)")
        # 3. scp across the air gap
        staged = cc.transfer(archive, work_dir / "cluster" / "inbox")
        log.append(f"transfer: {staged}")
        # 4. ch-tar2dir into node-local storage
        unpacked = cc.unpack(staged, work_dir / "cluster" / "tmpfs",
                             self.cluster)
        log.append(f"ch-tar2dir: {unpacked}")
        # 5. Slurm submission script (paper §IV-B/C command lines)
        from repro.launch import slurm
        script = slurm.render_script(
            job_name=definition.name, image_dir=str(unpacked),
            entrypoint=definition.entrypoint, nodes=nodes,
            ranks_per_node=ranks_per_node, threads_per_rank=threads_per_rank)
        log.append(f"sbatch script: {len(script.splitlines())} lines")
        runtime = cc.CapsuleRuntime(self.policy, self.cluster)
        return Deployment(image, staged, unpacked, runtime, script, log)


def intel_tensorflow_image(name: str = "intel-tf-horovod") -> cc.ImageDefinition:
    """The paper's exact image: Intel-optimized TF from the Intel AI Docker
    Hub, plus MPI and Horovod baked in at build time (§III-B)."""
    return cc.ImageDefinition(
        name=name,
        base="intelaipg/intel-optimized-tensorflow:1.11.0",
        requirements=("intel-tensorflow==1.11.0", "horovod>=0.15.0",
                      "mpi4py>=3.0.0", "keras>=2.2.0"),
        env={"OMP_NUM_THREADS": "48", "KMP_AFFINITY": "granularity=fine,compact",
             "KMP_BLOCKTIME": "1"},
        entrypoint="python",
        labels={"paper": "HPEC-2019-Brayford", "site": "LRZ"})
