"""Horovod-style data parallelism over jax.shard_map — the paper's §II-H.

The paper's recipe: take a single-process TensorFlow script, add four calls
(`hvd.init()`, pin one rank per node, wrap the optimizer in
``DistributedOptimizer``, broadcast initial variables) and run it under
``mpiexec``.  Gradient exchange is MPI *allreduce* — explicitly contrasted
with TensorFlow's parameter-server architecture (see
``repro.core.paramserver`` for that baseline).

The JAX mapping: one Horovod rank = one mesh slice along the data axes.
``allreduce`` = ``lax.pmean`` inside ``shard_map`` (XLA lowers it to the
ICI ring reduce — the same ring allreduce Horovod uses over OmniPath).
``make_train_step`` returns the paper-faithful replicated-weights DP step:
params/opt-state replicated (in_specs P()), batch sharded on dim 0, grads
pmean'd, every rank applies the identical update — bitwise-identical
replicas, exactly Horovod's contract.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.6 exposes it at top level
    shard_map = jax.shard_map
except AttributeError:                  # older jax: experimental namespace,
    from jax.experimental import shard_map as _esm  # check_vma was check_rep

    def shard_map(f, **kw):
        kw["check_rep"] = kw.pop("check_vma", True)
        return _esm.shard_map(f, **kw)

if hasattr(lax, "axis_size"):
    _axis_size = lax.axis_size
else:                                   # pre-axis_size jax: psum of a literal
    def _axis_size(ax):                 # constant-folds to a static int
        return lax.psum(1, ax)


# ---------------------------------------------------------------------------
# Inside-shard_map collective API (Horovod vocabulary)
# ---------------------------------------------------------------------------

def rank(axes: Sequence[str]) -> jnp.ndarray:
    """Linearized rank across ``axes`` (row-major, like MPI_Comm_rank)."""
    r = jnp.zeros((), jnp.int32)
    for ax in axes:
        r = r * _axis_size(ax) + lax.axis_index(ax)
    return r


def size(axes: Sequence[str]) -> int:
    s = 1
    for ax in axes:
        s *= _axis_size(ax)
    return s


def allreduce(x, axes: Sequence[str], average: bool = True):
    op = lax.pmean if average else lax.psum
    return jax.tree.map(lambda a: op(a, tuple(axes)), x)


def allgather(x, axes: Sequence[str]):
    def g(a):
        for ax in reversed(tuple(axes)):
            a = lax.all_gather(a, ax, axis=0)
            a = a.reshape((-1,) + a.shape[2:]) if a.ndim > 1 else a
        return a
    return jax.tree.map(g, x)


def hierarchical_allreduce(x, inner: Sequence[str], outer: Sequence[str],
                           average: bool = True):
    """Pod-aware allreduce: reduce-scatter over the ``inner`` (intra-pod)
    axes, allreduce the shard over the ``outer`` (inter-pod) axes, then
    all-gather back over ``inner``.

    Beyond-paper optimization (DESIGN.md §3): the inter-pod link carries
    1/|inner| of the gradient bytes instead of all of them — the same
    bandwidth shape as the paper's pruned 4:1 inter-island fat-tree, where
    hierarchical reduction is what kept their 32-node scaling near-linear.
    """
    inner, outer = tuple(inner), tuple(outer)
    n_inner = 1
    for ax in inner:
        n_inner *= _axis_size(ax)
    denom = float(n_inner)
    for ax in outer:
        denom *= _axis_size(ax)

    def per_leaf(a):
        flat = a.reshape(-1)
        pad = (-flat.shape[0]) % n_inner
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = lax.psum_scatter(flat, inner, scatter_dimension=0, tiled=True)
        shard = lax.psum(shard, outer)
        full = lax.all_gather(shard, inner, axis=0, tiled=True)
        if pad:
            full = full[:-pad]
        out = full.reshape(a.shape)
        return out / denom if average else out

    return jax.tree.map(per_leaf, x)


def broadcast(x, axes: Sequence[str], root: int = 0):
    """Broadcast from linearized rank ``root`` (Horovod's initial-variable
    broadcast).  Implemented as a masked psum — one allreduce, no tree."""
    r = rank(axes)

    def b(a):
        mask = (r == root).astype(a.dtype)
        return lax.psum(a * mask, tuple(axes))
    return jax.tree.map(b, x)


# ---------------------------------------------------------------------------
# DistributedOptimizer
# ---------------------------------------------------------------------------

class DistributedOptimizer:
    """Wraps a ``repro.optim`` optimizer: allreduce grads before update.

    Only meaningful inside shard_map (the paper's rank context).
    """

    def __init__(self, optimizer, axes: Sequence[str]):
        self.inner = optimizer
        self.axes = tuple(axes)

    def init(self, params):
        return self.inner.init(params)

    def update(self, grads, state, params):
        grads = allreduce(grads, self.axes, average=True)
        return self.inner.update(grads, state, params)


# ---------------------------------------------------------------------------
# The paper-faithful replicated-DP train step
# ---------------------------------------------------------------------------

def _batch_specs(batch, axes):
    spec = P(tuple(axes))
    return jax.tree.map(lambda _: spec, batch)


def make_train_step(loss_fn: Callable, optimizer, mesh: Mesh,
                    axes: Sequence[str] = ("data",),
                    donate: bool = True,
                    hierarchical: bool = False) -> Callable:
    """Returns jitted ``step(params, opt_state, batch) ->
    (params, opt_state, metrics)`` with Horovod-DP semantics:

    * params & optimizer state replicated on every chip,
    * batch sharded along its leading dim over ``axes``,
    * grads pmean'd (ring allreduce), update applied identically everywhere.

    hierarchical=True (multi-pod meshes): gradients take the pod-aware
    reduce-scatter/allreduce/all-gather path instead of one flat allreduce.
    """
    axes = tuple(axes)
    dist_opt = DistributedOptimizer(optimizer, axes)
    inner = tuple(a for a in axes if a != "pod")
    outer = tuple(a for a in axes if a == "pod")

    def local_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if hierarchical and outer:
            grads = hierarchical_allreduce(grads, inner, outer)
            updates, opt_state = optimizer.update(grads, opt_state, params)
        else:
            updates, opt_state = dist_opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              params, updates)
        metrics = dict(metrics, loss=loss)
        metrics = allreduce(metrics, axes, average=True)
        return params, opt_state, metrics

    def step(params, opt_state, batch):
        sharded = shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(), _batch_specs(batch, axes)),
            out_specs=(P(), P(), P()),
            check_vma=False)
        return sharded(params, opt_state, batch)

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def make_eval_step(loss_fn: Callable, mesh: Mesh,
                   axes: Sequence[str] = ("data",)) -> Callable:
    axes = tuple(axes)

    def local_eval(params, batch):
        loss, metrics = loss_fn(params, batch)
        return allreduce(dict(metrics, loss=loss), axes, average=True)

    def step(params, batch):
        return shard_map(
            local_eval, mesh=mesh,
            in_specs=(P(), _batch_specs(batch, axes)),
            out_specs=P(), check_vma=False)(params, batch)

    return jax.jit(step)
