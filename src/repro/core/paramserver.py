"""Parameter-server baseline — the architecture the paper's Horovod replaces.

TensorFlow's classic distributed mode: workers push gradients to central
parameter servers, which apply the update and serve fresh parameters back.
On a flat collective fabric this costs O(N · |params|) on the busiest link
(gather at the server + re-broadcast) versus ring allreduce's O(2 · |params|)
per link — the reason the paper (and Horovod) moved to allreduce.

We express the PS communication pattern with ``lax`` collectives so the
dry-run HLO exposes the contrast measurably: ``all_gather`` of the full
gradient pytree (server ingest) followed by a masked-psum broadcast of the
updated params (server egress).  ``benchmarks/allreduce_vs_ps.py`` parses
both programs' collective bytes out of the compiled HLO.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import hvd


def make_train_step(loss_fn: Callable, optimizer, mesh: Mesh,
                    axes: Sequence[str] = ("data",),
                    donate: bool = True) -> Callable:
    """Parameter-server-patterned ``step(params, opt_state, batch)``."""
    axes = tuple(axes)

    def local_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)

        # --- workers -> server: gather EVERY worker's full gradient -------
        gathered = jax.tree.map(
            lambda g: lax.all_gather(g, axes, axis=0), grads)

        # --- server applies the update (replica 0 is "the server"; all
        # replicas execute the same arithmetic on the gathered copy, which
        # is how a PS round looks from the collective-traffic viewpoint) ---
        mean_grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), gathered)
        updates, opt_state = optimizer.update(mean_grads, opt_state, params)
        new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)

        # --- server -> workers: broadcast refreshed parameters ------------
        new_params = hvd.broadcast(new_params, axes, root=0)

        metrics = hvd.allreduce(dict(metrics, loss=loss), axes)
        return new_params, opt_state, metrics

    def step(params, opt_state, batch):
        return hvd.shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(), jax.tree.map(lambda _: P(tuple(axes)), batch)),
            out_specs=(P(), P(), P()),
            check_vma=False)(params, opt_state, batch)

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())
