"""Pytree checkpointing with sharding metadata and rotation.

Checkpoints are written as a directory:
    step_000123/
        manifest.json      (tree structure, shapes, dtypes, shard specs)
        arrays.npz         (flattened leaves, host-gathered)
Restores rebuild the exact pytree (including scalar leaves) and re-place
arrays onto a target mesh sharding when given one.  Writes are atomic
(tmp dir + rename) so a killed job never leaves a half checkpoint — the
paper's batch jobs get requeued by Slurm and must restart cleanly.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: Path, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    dest = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        if arr.dtype == jnp.bfloat16:
            # np.savez cannot round-trip ml_dtypes; store widened, restore
            # casts back via the manifest/`like` dtype
            arr = arr.astype(np.float32)
        arrays[key] = arr
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if dest.exists():
        shutil.rmtree(dest)
    os.rename(tmp, dest)

    # rotation
    all_ckpts = sorted(p for p in ckpt_dir.iterdir()
                       if p.name.startswith("step_"))
    for old in all_ckpts[:-keep]:
        shutil.rmtree(old)
    return dest


def latest_step(ckpt_dir: Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: Path, like: Any, step: Optional[int] = None,
            sharding=None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``sharding``: optional pytree/callable of shardings
    to place leaves with."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    src = ckpt_dir / f"step_{step:09d}"
    data = np.load(src / "arrays.npz")

    keys = list(_flatten_with_paths(like).keys())
    missing = [k for k in keys if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint {src} missing leaves: {missing[:5]}...")

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    restored = []
    for key, leaf in zip(keys, leaves_like):
        arr = data[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        a = jnp.asarray(arr, dtype=want_dtype)
        restored.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if sharding is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sharding)
    return tree


def manifest(ckpt_dir: Path, step: Optional[int] = None) -> dict:
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
    return json.loads(
        (ckpt_dir / f"step_{step:09d}" / "manifest.json").read_text())
