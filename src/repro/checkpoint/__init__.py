from repro.checkpoint.ckpt import latest_step, manifest, restore, save
