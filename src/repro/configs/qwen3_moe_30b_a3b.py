"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L, d_model=2048, 32H (GQA kv=4, head_dim=128), per-expert d_ff=768,
vocab=151936, MoE 128 experts top-8 (fine-grained experts).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=768, vocab_size=151_936,
    num_experts=128, num_experts_per_tok=8,
    rope_theta=1_000_000.0, tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=64, vocab_size=307,
    num_experts=4, num_experts_per_tok=2,
    rope_theta=1_000_000.0, tie_embeddings=False,
)
