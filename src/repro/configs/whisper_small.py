"""whisper-small [audio enc-dec] — arXiv:2212.04356.

12L decoder / 12L encoder, d_model=768, 12H (GQA kv=12 = MHA), d_ff=3072,
vocab=51865.  Conv/mel frontend is a STUB per the brief: ``input_specs``
provides (B, 1500, 768) frame embeddings.  LayerNorm + GELU + learned
position embeddings (whisper style).  max_pos_embed covers the assigned
decode_32k shape (mechanical extension of the 448-position original).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, encoder_seq=1500,
    norm_type="layernorm", act="gelu", qkv_bias=True,
    max_pos_embed=32_768, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=211,
    encoder_layers=2, encoder_seq=32,
    norm_type="layernorm", act="gelu", qkv_bias=True,
    max_pos_embed=128, tie_embeddings=True,
)
