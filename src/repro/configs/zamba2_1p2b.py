"""zamba2-1.2b [hybrid] — arXiv:2411.15242.

38L Mamba2 backbone, d_model=2048, ssm_state=64; one weight-SHARED
attention+MLP block (32H, kv=32 MHA, d_ff=8192) applied every 6 mamba
layers (6 groups of 6 + 2 tail mamba layers).  The shared block uses a
sliding window in long-context mode so long_500k stays sub-quadratic.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, hybrid_attn_every=6,
    long_context_window=8192, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    num_layers=5, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=211,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, hybrid_attn_every=2,
    long_context_window=8192, tie_embeddings=True,
)
