"""deepseek-coder-33b [dense] — arXiv:2401.14196 (llama-arch).

62L, d_model=7168, 56H (GQA kv=8, head_dim=128), d_ff=19200, vocab=32256.
long_500k runs under the documented sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32_256,
    rope_theta=100_000.0,
    long_context_window=8192, tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=307,
    rope_theta=100_000.0,
    long_context_window=8192, tie_embeddings=False,
)
