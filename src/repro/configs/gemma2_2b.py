"""gemma2-2b [dense] — arXiv:2408.00118.

26L, d_model=2304, 8H (GQA kv=4), d_ff=9216, vocab=256000.
Same gemma2 features as the 27b: local/global alternation, softcaps,
post-block norms, scaled embeddings.
"""
from repro.configs.base import ModelConfig

_COMMON = dict(
    family="dense", local_global_pattern=True, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, embed_scale=True, act="gelu",
    tie_embeddings=True,
)

CONFIG = ModelConfig(
    name="gemma2-2b", num_layers=26, d_model=2304, num_heads=8,
    num_kv_heads=4, d_ff=9216, vocab_size=256_000, **_COMMON)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-2b-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, d_ff=512, vocab_size=307,
    **{**_COMMON, "sliding_window": 8})
