"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD, state-space duality).

48L, d_model=2048, attention-free, vocab=50280, ssm_state=128,
head_dim=64 (=> 64 SSD heads at expand=2), conv width 4, chunk 256.
Sub-quadratic by construction: long_500k decode is the O(1) recurrent step.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-1.3b-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=211,
    ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=16,
    tie_embeddings=True,
)
