"""3DGAN config — the paper's CERN workload (not part of the 40-pair table)."""
from repro.models.gan3d import GAN3DConfig

CONFIG = GAN3DConfig()
SMOKE_CONFIG = GAN3DConfig(name="3dgan-smoke", g_base=8, d_base=4)
