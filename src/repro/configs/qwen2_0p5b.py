"""qwen2-0.5b [dense] — arXiv:2407.10671.

24L, d_model=896, 14H (GQA kv=2), d_ff=4864, vocab=151936, QKV bias,
tied embeddings.  long_500k runs under the documented sliding-window
variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151_936,
    qkv_bias=True, rope_theta=1_000_000.0,
    long_context_window=8192, tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=307,
    qkv_bias=True, rope_theta=1_000_000.0,
    long_context_window=8192, tie_embeddings=True,
)
