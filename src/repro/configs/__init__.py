"""Architecture config registry.

``--arch <id>`` ids use the assigned names (dashes); modules use
underscores.  Every entry exports CONFIG (exact assigned numbers) and
SMOKE_CONFIG (reduced same-family variant for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import (InputShape, ModelConfig, SHAPES, input_specs,
                                shape_skips, synthesize_inputs)

_MODULES = {
    "whisper-small": "whisper_small",
    "gemma2-27b": "gemma2_27b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-0.5b": "qwen2_0p5b",
    "mamba2-1.3b": "mamba2_1p3b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gan3d": "gan3d",
}

ARCHS: List[str] = [a for a in _MODULES if a != "gan3d"]


def _module(arch: str):
    key = arch if arch in _MODULES else arch.replace("_", "-")
    if key not in _MODULES:
        key = {v: k for k, v in _MODULES.items()}.get(arch, key)
    if key not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[key]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG


def default_strategy(cfg: ModelConfig) -> str:
    """Baseline sharding strategy per DESIGN.md §3: TP for models whose
    replicated weights fit one chip's HBM; FSDP+TP for the big archs."""
    n = cfg.param_count()
    if n >= 20e9:
        return "fsdp_tp"
    return "dp_tp"
